"""Flash attention (online softmax) Pallas TPU kernel.

TPU adaptation of the FlashAttention insight: instead of CUDA shared-memory
tiles, KV blocks stream HBM->VMEM under explicit BlockSpecs; the innermost
grid dimension (KV blocks) is sequential on TPU, so the running (max, denom,
accumulator) live in VMEM scratch across iterations — no atomics, no
cross-block reduction tree.  Block shapes default to MXU-aligned (128).

Supports: causal masking, sliding windows (gemma2/hymba), logit softcap
(gemma2/grok), GQA via index-map head folding (q head h reads kv head h//G).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale, causal, window, cap, block_q, block_k, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0].astype(jnp.float32)                  # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap
    qp = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kp = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                               # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _final():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "cap", "scale", "block_q",
                              "block_k", "interpret", "n_kv_heads"))
def flash_attention(q, k, v, *, n_kv_heads, causal=True, window=0, cap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=False):
    """q: [B, Sq, H, hd]; k, v: [B, Sk, KV, hd] -> [B, Sq, H, hd].

    Self-attention positions (q_pos = kv_pos = iota).  GQA folded via the
    kv BlockSpec index map.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // n_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // H) * KV + (bh % H) // G, ki, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
