"""jit'd wrapper matching the model-side attention call signature."""
from __future__ import annotations

import jax.numpy as jnp

from . import kernel


def flash_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                    cap=0.0, scale=None, block_q=128, block_k=128,
                    interpret=False):
    """Self-attention entry point used by models.attention.attention_block.

    ``q_pos``/``kv_pos`` must be the contiguous iota of self-attention (the
    cache path uses the XLA decode attention instead); they are accepted for
    signature parity and ignored — positions are derived from block indices
    inside the kernel.
    """
    n_kv = k.shape[2]
    w = int(window) if not hasattr(window, "shape") else 0  # traced => full
    return kernel.flash_attention(
        q, k, v, n_kv_heads=n_kv, causal=causal, window=w, cap=float(cap),
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)
