"""Pure-jnp oracle: the blockwise online-softmax attention from the model
library (numerically identical algorithm, no Pallas)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import blockwise_attention


def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0,
                        scale=None):
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    kv_pos = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (B, Sk))
    return blockwise_attention(q, k, v, q_pos, kv_pos, causal=causal,
                               window=window, cap=cap, scale=scale)
