"""Pure-jnp oracles: sequential recurrence + chunked dual form."""
from repro.models.ssm import ssd_chunked, ssd_ref  # noqa: F401
