"""jit'd wrapper used by models.ssm when attn_impl selects the kernel."""
from __future__ import annotations

from . import kernel


def ssd(xs, dt, A, B_, C_, chunk: int = 128, interpret: bool = False):
    return kernel.ssd(xs, dt, A, B_, C_, chunk=chunk, interpret=interpret)
