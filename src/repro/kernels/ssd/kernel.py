"""Mamba-2 SSD chunk-scan Pallas TPU kernel.

TPU adaptation: the GPU implementation splits the chunk scan across thread
blocks with a separate state-passing kernel; on TPU the chunk axis is the
*sequential* minor grid dimension, so the inter-chunk SSM state lives in VMEM
scratch and flows across grid steps — one kernel, no state round-trip to
HBM.  Within a chunk everything is MXU matmuls (the "duality" insight):
decay-masked C·Bᵀ attention plus a rank-N state update.

Grid: (B*H, n_chunks).  Blocks: x (1, l, P), dt (1, l), B/C (1, l, N)
with the B/C index map folding heads (shared across H — n_groups=1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                h_scr, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # [l, P]
    dt = dt_ref[0].astype(jnp.float32)        # [l]
    A = a_ref[0].astype(jnp.float32)          # scalar (per head)
    B_ = b_ref[0].astype(jnp.float32)         # [l, N]
    C_ = c_ref[0].astype(jnp.float32)         # [l, N]

    a = dt * A                                # [l] log-decay per step
    cum = jnp.cumsum(a)                       # [l]
    seg = cum[:, None] - cum[None, :]         # [l, l]
    li = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    # mask before exp (future entries overflow; see models/ssm.py)
    L = jnp.exp(jnp.where(li >= lj, seg, -1e30))

    # intra-chunk (dual/attention form): ((C·Bᵀ) ⊙ L ⊙ dt) @ x
    cb = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * L * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += exp(cum) * (C @ h_prevᵀ);  h: [P, N]
    h = h_scr[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C_, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h = h * exp(Σa) + xᵀ @ (B ⊙ (dt · decay_to_end))
    decay_out = jnp.exp(cum[-1] - cum)        # [l]
    wB = B_ * (dt * decay_out)[:, None]       # [l, N]
    contrib = jax.lax.dot_general(x, wB, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_scr[...] = h * jnp.exp(cum[-1]) + contrib

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_out_ref[0] = h_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd(xs, dt, A, B_, C_, chunk: int = 128, interpret: bool = False):
    """xs: [B,S,H,P], dt: [B,S,H], A: [H], B_/C_: [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, Pd = xs.shape
    N = B_.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    xt = xs.transpose(0, 2, 1, 3).reshape(B * H, S, Pd)
    dtt = dt.transpose(0, 2, 1).reshape(B * H, S)
    at = jnp.tile(A, B)                                       # [B*H]

    def x_map(bh, ci):
        return (bh, ci, 0)

    def dt_map(bh, ci):
        return (bh, ci)

    def a_map(bh, ci):
        return (bh,)

    def bc_map(bh, ci):
        return (bh // H, ci, 0)

    def st_map(bh, ci):
        return (bh, 0, 0)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, Pd), x_map),
            pl.BlockSpec((1, chunk), dt_map),
            pl.BlockSpec((1,), a_map),
            pl.BlockSpec((1, chunk, N), bc_map),
            pl.BlockSpec((1, chunk, N), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, Pd), x_map),
            pl.BlockSpec((1, Pd, N), st_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, Pd), xs.dtype),
            jax.ShapeDtypeStruct((B * H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, at, B_, C_)
    return (y.reshape(B, H, S, Pd).transpose(0, 2, 1, 3),
            state.reshape(B, H, Pd, N))
