"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper) and ref.py (pure-jnp oracle).  Validated in
interpret mode on CPU; TPU is the lowering target (MXU-aligned block shapes,
sequential minor grid dimension carrying scratch accumulators — the TPU-
native substitute for CUDA thread-block programming).

The Akita paper itself has no kernel-level contribution (it is engine
infrastructure); these kernels belong to the training/serving framework the
engine's workloads run on: flash_attention (train/prefill attention) and
ssd (Mamba-2 chunked state-space scan).
"""
