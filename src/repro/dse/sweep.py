"""Sweep specification: design points over a topology's traced params.

A *design point* is a flat dict mapping axis paths to values.  Paths name
leaves of the engine's :class:`~repro.core.SimParams` pytree (traced —
hundreds of points share one compiled simulation) or, with the ``static.``
prefix, keyword arguments of the caller's build function (structural —
each distinct combination forces a rebuild/compile and forms its own
vmapped batch):

  ``conn_latency``            all connection latencies (cycles, >= 1)
  ``conn_latency[i]``         one connection (negative i counts from end)
  ``period.<kind>``           tick period of every instance of a kind
  ``period.<kind>[i]``        tick period of one instance
  ``kind.<kind>.<leaf>``      an opt-in model param (``ComponentKind.params``
                              pytree; nested dicts use dotted paths)
  ``static.<kwarg>``          build-function keyword (e.g. super_epoch)

:class:`SweepSpec` holds an ordered tuple of points, constructed by
``grid`` (cartesian product), ``random`` (uniform/log-uniform/choice
sampling), or ``explicit``.  ``split_static`` groups points by their
static-axis assignment so the runner compiles once per group; point order
within the spec is the canonical result order.
"""
from __future__ import annotations

import dataclasses
import itertools
import re
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimParams

STATIC_PREFIX = "static."

_INDEXED = re.compile(r"^(?P<base>.*?)\[(?P<ix>-?\d+)\]$")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """An ordered set of design points (dicts of axis path -> value)."""

    points: tuple[dict, ...]

    def __len__(self):
        return len(self.points)

    def __iter__(self):
        return iter(self.points)

    # -- constructors ------------------------------------------------------
    @staticmethod
    def grid(axes: dict[str, Sequence]) -> "SweepSpec":
        """Cartesian product of the axis value lists (insertion order:
        last axis varies fastest)."""
        names = list(axes)
        combos = itertools.product(*(list(axes[n]) for n in names))
        return SweepSpec(tuple(dict(zip(names, c)) for c in combos))

    @staticmethod
    def random(axes: dict[str, Any], n: int, seed: int = 0) -> "SweepSpec":
        """``n`` points sampled independently per axis.  Axis specs:
        ``(lo, hi)`` uniform float, ``(lo, hi, 'log')`` log-uniform, or a
        list/tuple of >2 (or non-numeric) entries = uniform choice."""
        rng = np.random.default_rng(seed)
        cols = {}
        for name, spec in axes.items():
            spec = tuple(spec)
            is_range = (len(spec) in (2, 3)
                        and all(isinstance(v, (int, float))
                                for v in spec[:2])
                        and (len(spec) == 2 or spec[2] == "log"))
            if is_range:
                lo, hi = float(spec[0]), float(spec[1])
                if len(spec) == 3:
                    cols[name] = list(np.exp(rng.uniform(
                        np.log(lo), np.log(hi), n)))
                else:
                    cols[name] = list(rng.uniform(lo, hi, n))
            else:
                cols[name] = [spec[int(i)]
                              for i in rng.integers(0, len(spec), n)]
        return SweepSpec(tuple(
            {name: cols[name][i] for name in axes} for i in range(n)))

    @staticmethod
    def explicit(points: Iterable[dict]) -> "SweepSpec":
        return SweepSpec(tuple(dict(p) for p in points))

    # -- static/traced split ----------------------------------------------
    def split_static(self):
        """Group points by their ``static.*`` assignment.

        Returns ``[(static_kwargs, indices, traced_points), ...]`` in first-
        appearance order; ``indices`` map each group's points back to spec
        order.
        """
        groups: dict[tuple, tuple[dict, list, list]] = {}
        for i, pt in enumerate(self.points):
            static = {k[len(STATIC_PREFIX):]: v for k, v in pt.items()
                      if k.startswith(STATIC_PREFIX)}
            traced = {k: v for k, v in pt.items()
                      if not k.startswith(STATIC_PREFIX)}
            key = tuple(sorted(static.items()))
            if key not in groups:
                groups[key] = (static, [], [])
            groups[key][1].append(i)
            groups[key][2].append(traced)
        return list(groups.values())


# ---------------------------------------------------------------------------
def _set_indexed(arr, path, ix, value):
    n = arr.shape[0]
    assert -n <= ix < n, f"{path}: index {ix} out of range for [{n}]"
    return arr.at[ix].set(jnp.asarray(value, arr.dtype))


def apply_point(params: SimParams, point: dict) -> SimParams:
    """Return ``params`` with one design point's traced assignments applied.

    Runs at trace-free build time (plain ``.at`` updates on tiny arrays);
    unknown paths raise ``KeyError`` so typos fail loudly before compile.
    """
    conn = params.conn_latency
    periods = dict(params.periods)
    kind = {k: v for k, v in params.kind.items()}
    for path, value in point.items():
        if path.startswith(STATIC_PREFIX):
            raise KeyError(f"static axis {path!r} reached apply_point — "
                           "route points through SweepSpec.split_static")
        m = _INDEXED.match(path)
        base, ix = (m["base"], int(m["ix"])) if m else (path, None)
        if base == "conn_latency":
            if ix is None:
                conn = jnp.full_like(conn, float(value))
            else:
                conn = _set_indexed(conn, path, ix, value)
        elif base.startswith("period."):
            kname = base[len("period."):]
            if kname not in periods:
                raise KeyError(f"{path!r}: unknown kind {kname!r} "
                               f"(have {sorted(periods)})")
            if ix is None:
                periods[kname] = jnp.full_like(periods[kname], float(value))
            else:
                periods[kname] = _set_indexed(periods[kname], path, ix, value)
        elif base.startswith("kind."):
            kname, _, leaf_path = base[len("kind."):].partition(".")
            if kname not in kind or not leaf_path:
                raise KeyError(f"{path!r}: unknown kind-param path "
                               f"(kinds with params: "
                               f"{sorted(k for k, v in kind.items() if v)})")
            kind[kname] = _set_leaf(kind[kname], leaf_path.split("."),
                                    value, path)
        else:
            raise KeyError(f"unknown sweep axis {path!r}")
    return SimParams(conn_latency=conn, periods=periods, kind=kind)


def _set_leaf(tree, keys, value, path):
    if not isinstance(tree, dict) or keys[0] not in tree:
        raise KeyError(f"{path!r}: no param leaf {'.'.join(keys)!r} "
                       f"(have {sorted(tree) if isinstance(tree, dict) else tree})")
    out = dict(tree)
    if len(keys) == 1:
        old = out[keys[0]]
        out[keys[0]] = jnp.asarray(value, jnp.asarray(old).dtype)
    else:
        out[keys[0]] = _set_leaf(out[keys[0]], keys[1:], value, path)
    return out


def stack_params(plist: Sequence[SimParams]) -> SimParams:
    """Stack per-point :class:`SimParams` into one batch (leading axis B)."""
    assert plist, "empty sweep"
    return jax.tree.map(lambda *xs: jnp.stack(xs), *plist)


def build_param_batch(sim, points: Sequence[dict]) -> SimParams:
    """``sim.default_params()`` + each point's assignments, stacked."""
    base = sim.default_params()
    return stack_params([apply_point(base, pt) for pt in points])
