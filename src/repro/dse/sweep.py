"""Sweep specification: design points over a topology's traced params.

A *design point* is a flat dict mapping axis paths to values.  Paths name
leaves of the engine's :class:`~repro.core.SimParams` pytree (traced —
hundreds of points share one compiled simulation) or, with the ``static.``
prefix, keyword arguments of the caller's build function (structural —
each distinct combination forces a rebuild/compile and forms its own
vmapped batch):

  ``conn_latency``            all connection latencies (cycles, >= 1)
  ``conn_latency[i]``         one connection (negative i counts from end)
  ``period.<kind>``           tick period of every instance of a kind
  ``period.<kind>[i]``        tick period of one instance
  ``kind.<kind>.<leaf>``      an opt-in model param (``ComponentKind.params``
                              pytree; nested dicts use dotted paths)
  ``static.<kwarg>``          build-function keyword (e.g. super_epoch)
  ``shape.<axis>``            a topology-family shape axis (instance
                              counts / wiring): lowered to traced activity
                              *masks* over one padded maximum-shape build,
                              NOT to per-shape compile groups (DSE.md
                              "Topology families")

:class:`SweepSpec` holds an ordered tuple of points, constructed by
``grid`` (cartesian product), ``random`` (uniform/log-uniform/choice
sampling), or ``explicit``.  ``split_static`` groups points by their
static-axis assignment so the runner compiles once per group; point order
within the spec is the canonical result order.

Axis paths can be checked *eagerly* — before any build or compile —
against the target simulation: pass ``validate_for=sim`` (or a
``TopologyFamily``) to a constructor, or call ``spec.validate(target)``;
unknown kinds/leaves raise a ``ValueError`` naming the bad path and the
valid axes instead of a deep ``KeyError`` mid-``run_sweep`` (which also
validates each compile group up front).
"""
from __future__ import annotations

import dataclasses
import itertools
import re
import zlib
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimParams

STATIC_PREFIX = "static."
SHAPE_PREFIX = "shape."

_INDEXED = re.compile(r"^(?P<base>.*?)\[(?P<ix>-?\d+)\]$")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """An ordered set of design points (dicts of axis path -> value)."""

    points: tuple[dict, ...]

    def __len__(self):
        return len(self.points)

    def __iter__(self):
        return iter(self.points)

    # -- constructors ------------------------------------------------------
    @staticmethod
    def grid(axes: dict[str, Sequence], validate_for=None) -> "SweepSpec":
        """Cartesian product of the axis value lists (insertion order:
        last axis varies fastest).  ``validate_for`` (a ``Simulation`` or
        ``TopologyFamily``) checks the axis paths eagerly at construction."""
        names = list(axes)
        combos = itertools.product(*(list(axes[n]) for n in names))
        spec = SweepSpec(tuple(dict(zip(names, c)) for c in combos))
        if validate_for is not None:
            spec.validate(validate_for)
        return spec

    @staticmethod
    def random(axes: dict[str, Any], n: int, seed: int = 0,
               validate_for=None) -> "SweepSpec":
        """``n`` points sampled independently per axis.  Axis specs:
        ``(lo, hi)`` uniform — float endpoints sample uniform floats,
        int endpoints sample uniform ints on the *inclusive* range —
        ``(lo, hi, 'log')`` log-uniform float, or a list/tuple of >2 (or
        non-numeric) entries = uniform choice.

        Each axis draws from its own RNG substream keyed on
        ``(seed, axis name)``: the values one axis yields under a seed
        never depend on the other axes' spec styles, their count, or
        dict order, and int axes come back as Python ints (JSON-clean
        rows; pinned by ``tests/dse/test_sweep_spec.py``).
        """
        cols = {}
        for name, spec in axes.items():
            rng = np.random.default_rng([seed, zlib.crc32(name.encode())])
            kind, *args = parse_axis_spec(spec)
            if kind == "log":
                lo, hi = args
                cols[name] = [float(v) for v in np.exp(rng.uniform(
                    np.log(lo), np.log(hi), n))]
            elif kind == "int":
                lo, hi = args
                cols[name] = [int(v) for v in rng.integers(lo, hi + 1, n)]
            elif kind == "float":
                lo, hi = args
                cols[name] = [float(v) for v in rng.uniform(lo, hi, n)]
            else:
                values = args[0]
                cols[name] = [_py_scalar(values[int(i)])
                              for i in rng.integers(0, len(values), n)]
        out = SweepSpec(tuple(
            {name: cols[name][i] for name in axes} for i in range(n)))
        if validate_for is not None:
            out.validate(validate_for)
        return out

    @staticmethod
    def explicit(points: Iterable[dict], validate_for=None,
                 ragged: bool = False) -> "SweepSpec":
        """An ordered spec from caller-supplied point dicts.

        Points that share a ``static.*`` assignment stack into one
        vmapped compile group, so they must assign the same axis keys —
        a missing or extra key would otherwise surface much later as an
        opaque stacking/lookup failure deep in a sweep or search round.
        The mismatch raises here instead, naming the offending point
        index and keys.  Points in *different* static groups may use
        different traced axes (each group stacks separately).
        ``ragged=True`` skips the check entirely.
        """
        pts = tuple(dict(p) for p in points)
        if not ragged:
            groups: dict[frozenset, tuple[int, set]] = {}
            for i, p in enumerate(pts):
                static = frozenset(kv for kv in p.items()
                                   if kv[0].startswith(STATIC_PREFIX))
                j, keys0 = groups.setdefault(static, (i, set(p)))
                if set(p) != keys0:
                    missing = sorted(keys0 - set(p))
                    extra = sorted(set(p) - keys0)
                    raise ValueError(
                        f"explicit point {i} has inconsistent axis keys "
                        f"(missing {missing}, extra {extra} vs point "
                        f"{j}'s {sorted(keys0)}, the first point of its "
                        "static group); points that stack into one "
                        "compile group must assign identical axes "
                        "(ragged=True skips this check)")
        spec = SweepSpec(pts)
        if validate_for is not None:
            spec.validate(validate_for)
        return spec

    # -- eager validation --------------------------------------------------
    @property
    def axes(self) -> list[str]:
        """Union of axis paths across points, in first-appearance order."""
        seen: list[str] = []
        for pt in self.points:
            for k in pt:
                if k not in seen:
                    seen.append(k)
        return seen

    def has_shape_axes(self) -> bool:
        return any(k.startswith(SHAPE_PREFIX) for k in self.axes)

    def summary(self) -> dict:
        """A small JSON-safe description of the spec — axis names, value
        counts per axis, point count — for telemetry (``sweep.start``
        events carry it) and logs.  Never materializes values: a
        192-point grid summarizes to a few dozen bytes.
        """
        counts: dict[str, set] = {}
        for pt in self.points:
            for k, v in pt.items():
                counts.setdefault(k, set()).add(
                    v if isinstance(v, (int, float, str, bool)) else str(v))
        return {"n_points": len(self.points),
                "axes": {k: len(vs) for k, vs in counts.items()}}

    def validate(self, target, static_ok: Sequence[str] | None = None
                 ) -> "SweepSpec":
        """Check every axis path against ``target`` (a ``Simulation`` or a
        ``TopologyFamily``) *before* anything is built or compiled.

        Raises ``ValueError`` naming each bad path and the valid axes —
        instead of the deep ``KeyError`` an unknown kind/leaf (e.g.
        ``period.l1x``) would otherwise surface mid-``run_sweep``.
        ``static_ok`` (optional) whitelists ``static.*`` kwarg names
        (``run_sweep`` derives it from the build function's signature).
        Returns ``self`` for chaining.
        """
        family = getattr(target, "shape_max", None)
        sim = target.sim if family is not None else target
        params = sim.default_params()
        errors = []
        for path in self.axes:
            if path.startswith(STATIC_PREFIX):
                name = path[len(STATIC_PREFIX):]
                if static_ok is not None and name not in static_ok:
                    errors.append(f"{path!r}: build function accepts no "
                                  f"keyword {name!r} "
                                  f"(have {sorted(static_ok)})")
            elif path.startswith(SHAPE_PREFIX):
                name = path[len(SHAPE_PREFIX):]
                if family is None:
                    errors.append(
                        f"{path!r}: shape axes need a topology family "
                        "(a build function returning TopologyFamily); "
                        "this target is a plain Simulation")
                elif name not in family:
                    errors.append(f"{path!r}: unknown family shape axis "
                                  f"(have {sorted(family)})")
            else:
                err = axis_error(params, path)
                if err:
                    errors.append(err)
        if errors:
            raise ValueError(
                "invalid sweep axes:\n  " + "\n  ".join(errors)
                + "\nvalid axes for this target:\n  "
                + "\n  ".join(valid_axes(params, family)))
        return self

    # -- static/traced split ----------------------------------------------
    def split_static(self):
        """Group points by their ``static.*`` assignment.

        Returns ``[(static_kwargs, indices, traced_points), ...]`` in first-
        appearance order; ``indices`` map each group's points back to spec
        order.
        """
        groups: dict[tuple, tuple[dict, list, list]] = {}
        for i, pt in enumerate(self.points):
            static = {k[len(STATIC_PREFIX):]: v for k, v in pt.items()
                      if k.startswith(STATIC_PREFIX)}
            traced = {k: v for k, v in pt.items()
                      if not k.startswith(STATIC_PREFIX)}
            key = tuple(sorted(static.items()))
            if key not in groups:
                groups[key] = (static, [], [])
            groups[key][1].append(i)
            groups[key][2].append(traced)
        return list(groups.values())


# ---------------------------------------------------------------------------
def _py_scalar(v):
    """Numpy scalar -> plain Python scalar (rows stay JSON-clean)."""
    return v.item() if isinstance(v, np.generic) else v


def parse_axis_spec(spec) -> tuple:
    """Classify one :meth:`SweepSpec.random` axis spec — the single
    source of truth for spec detection, shared with the BO surrogate's
    axis encoders (``repro.dse.search.bo``) so sampling and encoding can
    never drift apart.

    Returns ``("log", lo, hi)``, ``("int", lo, hi)`` (both endpoints
    Python ints — the *inclusive* integer range), ``("float", lo, hi)``,
    or ``("choice", values)``.
    """
    spec = tuple(spec)
    is_range = (len(spec) in (2, 3)
                and all(isinstance(v, (int, float))
                        and not isinstance(v, bool)
                        for v in spec[:2])
                and (len(spec) == 2 or spec[2] == "log"))
    if not is_range:
        return ("choice", spec)
    if len(spec) == 3:
        return ("log", float(spec[0]), float(spec[1]))
    if all(isinstance(v, int) for v in spec[:2]):
        return ("int", int(spec[0]), int(spec[1]))
    return ("float", float(spec[0]), float(spec[1]))


def split_shape(point: dict) -> tuple[dict, dict]:
    """Split one design point into (shape assignment, traced assignments).

    ``shape.<axis>`` keys come back stripped of their prefix; everything
    else (the traced axes) is returned untouched for ``apply_point``.
    """
    shape = {k[len(SHAPE_PREFIX):]: v for k, v in point.items()
             if k.startswith(SHAPE_PREFIX)}
    traced = {k: v for k, v in point.items()
              if not k.startswith(SHAPE_PREFIX)}
    return shape, traced


def _leaf_paths(tree, prefix=""):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _leaf_paths(tree[k], f"{prefix}{k}.")
        return out
    return [prefix[:-1]] if prefix else []


def valid_axes(params: SimParams, shape_axes=None) -> list[str]:
    """Human-readable list of every sweepable axis of a target."""
    axes = ["conn_latency", "conn_latency[i]"]
    for k in sorted(params.periods):
        axes += [f"period.{k}", f"period.{k}[i]"]
    for k in sorted(params.kind):
        for leaf in _leaf_paths(params.kind[k]):
            axes.append(f"kind.{k}.{leaf}")
    for name in sorted(shape_axes or ()):
        axes.append(f"shape.{name}")
    axes.append("static.<build kwarg>")
    return axes


def axis_error(params: SimParams, path: str) -> str | None:
    """``None`` if ``path`` names a traced leaf of ``params``, else a
    one-line description of why it does not."""
    m = _INDEXED.match(path)
    base, ix = (m["base"], int(m["ix"])) if m else (path, None)

    def ix_ok(n):
        if ix is not None and not -n <= ix < n:
            return f"{path!r}: index {ix} out of range for [{n}]"
        return None

    if base == "conn_latency":
        return ix_ok(params.conn_latency.shape[0])
    if base.startswith("period."):
        kname = base[len("period."):]
        if kname not in params.periods:
            return (f"{path!r}: unknown kind {kname!r} "
                    f"(have {sorted(params.periods)})")
        return ix_ok(params.periods[kname].shape[0])
    if base.startswith("kind."):
        if ix is not None:
            return f"{path!r}: kind-param axes are not indexable"
        kname, _, leaf = base[len("kind."):].partition(".")
        if kname not in params.kind or not params.kind[kname]:
            return (f"{path!r}: kind {kname!r} has no params "
                    f"(kinds with params: "
                    f"{sorted(k for k, v in params.kind.items() if v)})")
        tree = params.kind[kname]
        for key in leaf.split("."):
            if not isinstance(tree, dict) or key not in tree:
                return (f"{path!r}: no param leaf {leaf!r} on kind "
                        f"{kname!r} (have {_leaf_paths(params.kind[kname])})")
            tree = tree[key]
        return None
    return f"unknown sweep axis {path!r}"


def _set_indexed(arr, path, ix, value):
    n = arr.shape[0]
    assert -n <= ix < n, f"{path}: index {ix} out of range for [{n}]"
    return arr.at[ix].set(jnp.asarray(value, arr.dtype))


def apply_point(params: SimParams, point: dict) -> SimParams:
    """Return ``params`` with one design point's traced assignments applied.

    Runs at trace-free build time (plain ``.at`` updates on tiny arrays);
    unknown paths raise ``KeyError`` so typos fail loudly before compile.
    """
    conn = params.conn_latency
    periods = dict(params.periods)
    kind = {k: v for k, v in params.kind.items()}
    for path, value in point.items():
        if path.startswith(STATIC_PREFIX):
            raise KeyError(f"static axis {path!r} reached apply_point — "
                           "route points through SweepSpec.split_static")
        if path.startswith(SHAPE_PREFIX):
            raise KeyError(f"shape axis {path!r} reached apply_point — "
                           "route points through split_shape and a "
                           "TopologyFamily (masks, not param leaves)")
        m = _INDEXED.match(path)
        base, ix = (m["base"], int(m["ix"])) if m else (path, None)
        if base == "conn_latency":
            if ix is None:
                conn = jnp.full_like(conn, float(value))
            else:
                conn = _set_indexed(conn, path, ix, value)
        elif base.startswith("period."):
            kname = base[len("period."):]
            if kname not in periods:
                raise KeyError(f"{path!r}: unknown kind {kname!r} "
                               f"(have {sorted(periods)})")
            if ix is None:
                periods[kname] = jnp.full_like(periods[kname], float(value))
            else:
                periods[kname] = _set_indexed(periods[kname], path, ix, value)
        elif base.startswith("kind."):
            kname, _, leaf_path = base[len("kind."):].partition(".")
            if kname not in kind or not leaf_path:
                raise KeyError(f"{path!r}: unknown kind-param path "
                               f"(kinds with params: "
                               f"{sorted(k for k, v in kind.items() if v)})")
            kind[kname] = _set_leaf(kind[kname], leaf_path.split("."),
                                    value, path)
        else:
            raise KeyError(f"unknown sweep axis {path!r}")
    return dataclasses.replace(params, conn_latency=conn, periods=periods,
                               kind=kind)


def _set_leaf(tree, keys, value, path):
    if not isinstance(tree, dict) or keys[0] not in tree:
        raise KeyError(f"{path!r}: no param leaf {'.'.join(keys)!r} "
                       f"(have {sorted(tree) if isinstance(tree, dict) else tree})")
    out = dict(tree)
    if len(keys) == 1:
        old = out[keys[0]]
        out[keys[0]] = jnp.asarray(value, jnp.asarray(old).dtype)
    else:
        out[keys[0]] = _set_leaf(out[keys[0]], keys[1:], value, path)
    return out


def stack_trees(trees: Sequence) -> Any:
    """Stack a list of identically-structured pytrees into one batch
    (leading axis B), materializing fresh buffers per leaf."""
    assert trees, "empty batch"
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_params(plist: Sequence[SimParams]) -> SimParams:
    """Stack per-point :class:`SimParams` into one batch (leading axis B)."""
    return stack_trees(plist)


def build_param_batch(sim, points: Sequence[dict]) -> SimParams:
    """``sim.default_params()`` + each point's assignments, stacked."""
    base = sim.default_params()
    return stack_params([apply_point(base, pt) for pt in points])
