"""Persistent cross-process caching for DSE campaigns (DSE.md "Sharded
sweeps and the persistent cache").

A fleet of short-lived sweep/search jobs (CI shards, search workers,
one-config-per-process campaigns) pays the family cold compile — ~7s on
the memsys family, 0.53 shapes/s cold vs 51.5 warm (BENCH_struct.json)
— once *per process* unless compiled executables outlive the process.
This module makes them outlive it, at two layers:

* **XLA executables** — :func:`ensure_enabled` wires
  ``jax.experimental.compilation_cache`` to an on-disk directory (the
  ``REPRO_CACHE_DIR`` environment variable or :func:`configure`), with
  the min-compile-time/min-entry-size thresholds dropped to zero so
  every sweep executable persists.  ``run_sweep`` calls this on entry
  ("enable-on-first-sweep"), so any process that runs a sweep with a
  cache dir configured reads and writes the shared cache; the second
  process of a campaign deserializes instead of compiling.
* **Whole AOT executables** — the jax persistent cache skips XLA
  *compilation* but a fresh process still re-traces and re-lowers every
  program, and on the batched while-loop engine trace+lower is seconds
  per rung — the dominant warm-start cost once compiles are cached.
  :func:`get_executable` / :func:`put_executable` persist the runner's
  big batched executables whole (``jax.experimental
  .serialize_executable``; one blob file per ``(sim signature, batch
  size, shard topology, backend)``), so the second process *loads* each
  rung executable in ~0.1s with **no tracing at all**.  A loaded
  executable is the same compiled binary — results are bit-identical by
  construction, donation semantics included.
* **Repro's own artifacts** — the executables are necessary but not
  sufficient: a fresh process must also *ask for the same executables*.
  :class:`DseCache` is a small JSON store (one file in the same cache
  dir) keyed on ``(simulation structural signature, batch size, shard
  topology, jax + repro cache version)`` that persists the three
  decisions a warm process made so a cold one can repeat them exactly:

  - the **autotuned chunk-ladder winner** (``tuned_top``) — otherwise
    the second process re-probes and may pick a different rung, missing
    the persisted executables entirely;
  - the **warm-ladder rung set** (``rungs``) — which batch sizes a
    sweep of this shape actually compiled, so ``run_rounds`` can
    pre-warm them all from the persistent cache before the first timed
    round instead of faulting them in mid-sweep;
  - the **family max-shape union** (``family``) — ``memoize_build``
    grows a family's padded maximum across search rounds; persisting
    the union lets the next process build the family at the final
    maximum in one shot (one build, and an executable key that matches
    the cached one).

Every lookup emits ``cache.hit`` / ``cache.miss`` (and writes emit
``cache.write``) on the telemetry bus with payload byte sizes, plus a
``dse.cache.hit_rate`` gauge the ``/campaign`` dashboard surfaces —
a campaign that silently misses its cache is a perf bug worth seeing.

The directory is size-capped: :func:`gc` evicts least-recently-used
files (executable hits bump mtime) down to ``REPRO_CACHE_MAX_BYTES`` /
``configure(max_bytes=...)``, emitting ``cache.evict`` per file — so a
long-lived shared cache dir serves many campaigns without growing
forever.  The artifact store itself is never evicted (a few KB of
decisions whose loss would cost a re-probe).

Nothing here is load-bearing for correctness: with no cache dir
configured every function is a cheap no-op, artifacts only shortcut
decisions that would otherwise be re-derived, and a corrupt or
concurrently-rewritten store file degrades to a miss.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
import threading
import weakref

import jax

from repro.obs.bus import BUS

ENV_DIR = "REPRO_CACHE_DIR"
ENV_MAX_BYTES = "REPRO_CACHE_MAX_BYTES"

# Bump when the artifact semantics change (keys embed it, so old stores
# simply stop matching instead of poisoning new processes).
CACHE_VERSION = 1

STORE_NAME = "repro_dse_artifacts.json"

_lock = threading.Lock()
_cfg: dict = {"dir": None, "jax_enabled": False, "max_bytes": None}
_store: "DseCache | None" = None
_counts = {"hits": 0, "misses": 0, "writes": 0, "evictions": 0}

_SIM_SIGS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
def configure(cache_dir: str | None,
              max_bytes: int | None = None) -> None:
    """Set (or clear, with ``None``) the campaign cache directory.

    Precedence: an explicit ``configure()`` beats the ``REPRO_CACHE_DIR``
    environment variable.  The jax compilation cache is wired lazily by
    :func:`ensure_enabled` (``run_sweep`` calls it on entry), so merely
    configuring a directory costs nothing.

    ``max_bytes`` caps the cache directory's total size: when a write
    pushes it over, :func:`gc` evicts least-recently-used files until it
    fits (``None`` falls back to the ``REPRO_CACHE_MAX_BYTES``
    environment variable; with neither set the cache grows unbounded).
    Each ``configure()`` call resets the cap, so a test that sets one
    cannot leak it into the next.
    """
    global _store
    with _lock:
        _cfg["dir"] = cache_dir
        _cfg["max_bytes"] = None if max_bytes is None else int(max_bytes)
        _store = None


def cache_dir() -> str | None:
    """The effective cache directory, or ``None`` when caching is off."""
    return _cfg["dir"] or os.environ.get(ENV_DIR) or None


def max_cache_bytes() -> int | None:
    """The effective size cap for :func:`gc`, or ``None`` (unbounded).
    ``configure(max_bytes=...)`` beats ``REPRO_CACHE_MAX_BYTES``."""
    if _cfg["max_bytes"] is not None:
        return int(_cfg["max_bytes"])
    env = os.environ.get(ENV_MAX_BYTES)
    try:
        return int(env) if env else None
    except ValueError:
        return None


def active() -> bool:
    """Whether a cache directory is configured (artifact lookups and the
    persistent compilation cache are live)."""
    return cache_dir() is not None


def ensure_enabled() -> bool:
    """Idempotently wire the jax persistent compilation cache to the
    configured directory; returns whether caching is active.

    Drops jax's min-compile-time and min-entry-size thresholds so every
    sweep executable persists (the default 1s floor would skip the small
    liveness/rung programs whose re-compiles still stall a fresh
    process).  Called by ``run_sweep`` on entry — the first sweep of a
    process enables the cache for everything after it.
    """
    d = cache_dir()
    if d is None:
        return False
    with _lock:
        if _cfg["jax_enabled"]:
            return True
        os.makedirs(d, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", d)
        for knob, v in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                        ("jax_persistent_cache_min_entry_size_bytes", -1)):
            try:
                jax.config.update(knob, v)
            except (AttributeError, ValueError):  # pragma: no cover
                pass                              # older jax: keep defaults
        # jax latches the enabled/disabled decision at the *first*
        # compile of the process: a build that jitted anything before
        # this point initialized the cache as "no directory", and the
        # config update alone never re-checks.  Un-latch so the next
        # compile re-initializes against the directory we just set.
        try:
            from jax._src import compilation_cache as _cc
            if getattr(_cc, "_cache_initialized", False) \
                    and getattr(_cc, "_cache", None) is None:
                _cc.reset_cache()
        except Exception:             # pragma: no cover - jax internals
            pass                      # moved: stay with latched behavior
        _cfg["jax_enabled"] = True
    if BUS.active:
        BUS.emit("cache.enable", dir=d, jax=jax.__version__)
    gc()     # shrink a pre-existing over-cap dir at startup, not mid-sweep
    return True


def store() -> "DseCache | None":
    """The process-wide artifact store (``None`` when caching is off)."""
    global _store
    d = cache_dir()
    if d is None:
        return None
    with _lock:
        if _store is None or _store.path != os.path.join(d, STORE_NAME):
            _store = DseCache(os.path.join(d, STORE_NAME))
    return _store


def stats() -> dict:
    """Process-wide artifact hit/miss/write counts (tests + dashboards)."""
    return dict(_counts)


def _note(kind: str, key: str, hit: bool, nbytes: int = 0) -> None:
    _counts["hits" if hit else "misses"] += 1
    if BUS.active:
        BUS.emit("cache.hit" if hit else "cache.miss", what=kind, key=key,
                 bytes=nbytes)
        BUS.count("dse.cache.hits" if hit else "dse.cache.misses")
        seen = _counts["hits"] + _counts["misses"]
        BUS.gauge("dse.cache.hit_rate", _counts["hits"] / seen)


# ---------------------------------------------------------------------------
# size-capped LRU GC
# ---------------------------------------------------------------------------
def gc(limit: int | None = None) -> int:
    """Evict least-recently-used cache files until the directory fits
    the size cap; returns the number of files evicted.

    Candidates are every file under the cache dir — AOT executable
    blobs (``exec_*.bin``) and the jax compilation-cache entries —
    *except* the artifact store (:data:`STORE_NAME`, a few KB of
    decisions that regenerating would cost a re-probe) and in-progress
    temp files.  Recency is file mtime: :func:`get_executable` bumps it
    on every hit, so a campaign's hot rung executables survive while a
    long-dead topology's blobs age out.  ``limit`` overrides the
    configured cap (:func:`max_cache_bytes`); with no cap (or no cache
    dir) this is a no-op.  Every eviction emits a ``cache.evict`` event
    and bumps ``dse.cache.evictions``; the post-GC directory size lands
    on the ``dse.cache.bytes`` gauge.

    Called automatically after every executable write (the only writes
    big enough to matter) and once at :func:`ensure_enabled` — a
    pre-existing over-cap directory shrinks at startup, not mid-sweep.
    """
    d = cache_dir()
    cap = max_cache_bytes() if limit is None else int(limit)
    if d is None or cap is None:
        return 0
    entries: list[tuple[int, int, str]] = []
    total = 0
    for root, _, files in os.walk(d):
        for name in files:
            if name == STORE_NAME or name.startswith(".dse_"):
                continue
            p = os.path.join(root, name)
            try:
                st = os.stat(p)
            except OSError:
                continue
            entries.append((st.st_mtime_ns, st.st_size, p))
            total += st.st_size
    if BUS.active:
        BUS.gauge("dse.cache.bytes", total)
    if total <= cap:
        return 0
    evicted = 0
    freed = 0
    for _, size, p in sorted(entries):        # oldest mtime first
        if total - freed <= cap:
            break
        try:
            os.unlink(p)
        except OSError:                       # raced another process
            continue
        freed += size
        evicted += 1
        _counts["evictions"] += 1
        if BUS.active:
            BUS.emit("cache.evict", path=os.path.relpath(p, d),
                     bytes=size)
            BUS.count("dse.cache.evictions")
    if BUS.active and evicted:
        BUS.gauge("dse.cache.bytes", total - freed)
    return evicted


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------
def _hash(obj) -> str:
    blob = json.dumps(obj, sort_keys=True, default=repr).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def sim_signature(sim) -> str:
    """A structural signature of a built :class:`~repro.core.Simulation`,
    stable across processes: kind layout + connection count + the
    abstract (shape, dtype) tree of its default params.

    Two processes that build the same topology get the same signature;
    any structural difference (instance counts, port counts, padding,
    super-epoch, param schema) changes it — exactly the things that
    change the compiled executables an artifact points at.
    """
    sig = _SIM_SIGS.get(sim)
    if sig is None:
        params = sim.default_params()
        leaves, treedef = jax.tree.flatten(params)
        sig = _SIM_SIGS[sim] = _hash({
            "kinds": [(k.name, int(k.n_instances), int(k.n_ports))
                      for k in sim.kinds],
            "n_conn": int(sim.n_conn),
            "cap_phys": int(sim.cap_phys),
            "super_epoch": int(sim.super_epoch),
            "donate": bool(sim.donate),
            "params": [(str(jax.numpy.shape(x)),
                        str(jax.numpy.asarray(x).dtype)) for x in leaves],
            "treedef": str(treedef),
        })
    return sig


def _key(kind: str, **parts) -> str:
    return f"{kind}:" + _hash(dict(parts, jax=jax.__version__,
                                   cache_version=CACHE_VERSION))


def family_build_key(build_fn, args: tuple, kwargs: dict) -> str:
    """Key for a memoized family build: the build function's identity
    plus its non-shape arguments (values via ``repr`` — build kwargs are
    plain scalars/strings in practice)."""
    fn = getattr(build_fn, "__wrapped__", build_fn)
    return _key("family",
                fn=f"{getattr(fn, '__module__', '?')}."
                   f"{getattr(fn, '__qualname__', repr(fn))}",
                args=[repr(a) for a in args],
                kwargs={k: repr(v) for k, v in sorted(kwargs.items())})


# ---------------------------------------------------------------------------
# the JSON artifact store
# ---------------------------------------------------------------------------
class DseCache:
    """A tiny persistent key→JSON-value store (one file, atomic writes).

    Reads reload the file only when its mtime/size changed (cheap stat
    per lookup); writes read-merge-replace under a process lock with
    ``os.replace`` so concurrent processes never see a torn file.  Two
    processes racing on the *same* key last-write-wins — every value
    here is a shortcut, not a source of truth, so that is safe.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._data: dict = {}
        self._stamp: tuple | None = None

    # -- file I/O ----------------------------------------------------------
    def _refresh(self) -> None:
        try:
            st = os.stat(self.path)
            stamp = (st.st_mtime_ns, st.st_size)
        except OSError:
            self._data, self._stamp = {}, None
            return
        if stamp == self._stamp:
            return
        try:
            with open(self.path) as fh:
                raw = json.load(fh)
            self._data = raw.get("entries", {}) \
                if raw.get("version") == CACHE_VERSION else {}
        except (OSError, ValueError):     # torn/corrupt file -> miss
            self._data = {}
        self._stamp = stamp

    def _flush(self) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        body = {"version": CACHE_VERSION, "entries": self._data}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path) or ".",
                                   prefix=".dse_cache_")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(body, fh, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:                    # read-only dir: stay in-memory
            try:
                os.unlink(tmp)
            except OSError:
                pass
        try:
            st = os.stat(self.path)
            self._stamp = (st.st_mtime_ns, st.st_size)
        except OSError:
            self._stamp = None

    # -- API ---------------------------------------------------------------
    def get(self, key: str, kind: str = "artifact"):
        with self._lock:
            self._refresh()
            v = self._data.get(key)
        hit = v is not None
        _note(kind, key, hit,
              len(json.dumps(v).encode()) if hit else 0)
        return v

    def put(self, key: str, value, kind: str = "artifact") -> None:
        blob = json.loads(json.dumps(value))   # force JSON-cleanliness now
        with self._lock:
            self._refresh()                    # merge concurrent writers
            self._data[key] = blob
            self._flush()
        _counts["writes"] += 1
        if BUS.active:
            BUS.emit("cache.write", what=kind, key=key,
                     bytes=len(json.dumps(blob).encode()))
            BUS.count("dse.cache.writes")


# ---------------------------------------------------------------------------
# artifact accessors (all no-ops without a configured cache dir)
# ---------------------------------------------------------------------------
def _maybe_enable_at_import() -> None:
    """With ``REPRO_CACHE_DIR`` in the environment, wire the jax cache
    the moment ``repro.dse`` is imported — jax latches the cache
    decision at the process's *first* compile, and builds typically
    compile before the first sweep; enabling early means those
    executables persist too, so the second process of a campaign starts
    with a complete cache instead of back-filling build-time programs."""
    if os.environ.get(ENV_DIR):
        ensure_enabled()


_maybe_enable_at_import()


def get_tuned_top(sim, devices: int) -> int | None:
    """The persisted autotune winner for (this topology, this shard
    topology), or ``None``."""
    s = store()
    if s is None:
        return None
    v = s.get(_key("tuned_top", sim=sim_signature(sim), devices=devices),
              kind="tuned_top")
    return int(v) if v is not None else None


def put_tuned_top(sim, devices: int, top: int) -> None:
    s = store()
    if s is not None:
        s.put(_key("tuned_top", sim=sim_signature(sim), devices=devices),
              int(top), kind="tuned_top")


def get_rung_set(sim, b: int, devices: int) -> list[int] | None:
    """The rung batch sizes a previous process compiled for a B-point
    sweep of this topology at this shard topology."""
    s = store()
    if s is None:
        return None
    v = s.get(_key("rungs", sim=sim_signature(sim), b=b, devices=devices),
              kind="rungs")
    return sorted(int(r) for r in v) if v else None


def put_rung_set(sim, b: int, devices: int, rungs) -> None:
    s = store()
    if s is None:
        return
    key = _key("rungs", sim=sim_signature(sim), b=b, devices=devices)
    with s._lock:
        s._refresh()
        old = s._data.get(key) or []
    merged = sorted({int(r) for r in (*old, *rungs)})
    if merged != sorted(int(r) for r in old):
        s.put(key, merged, kind="rungs")


def get_family_shape(build_key: str) -> dict | None:
    """The persisted max-shape union of a memoized family build."""
    s = store()
    if s is None:
        return None
    v = s.get(build_key, kind="family")
    return {k: int(x) for k, x in v.items()} if v else None


def put_family_shape(build_key: str, shape_max: dict) -> None:
    s = store()
    if s is None:
        return
    with s._lock:
        s._refresh()
        old = s._data.get(build_key) or {}
    merged = dict(old)
    for k, v in shape_max.items():
        merged[k] = max(int(v), int(merged.get(k, 0)))
    if merged != old:
        s.put(build_key, merged, kind="family")


# ---------------------------------------------------------------------------
# whole-executable persistence (skips trace + lower, not just compile)
# ---------------------------------------------------------------------------
def _exec_key(sim, b: int, devices: int) -> str:
    return _key("exec", sim=sim_signature(sim), b=int(b),
                devices=int(devices), platform=jax.default_backend())


def _exec_path(key: str) -> str:
    return os.path.join(cache_dir(), f"exec_{key.split(':', 1)[1]}.bin")


def get_executable(sim, b: int, devices: int):
    """Rehydrate the persisted AOT executable for (topology, batch size,
    shard topology), or ``None``.

    A load failure of any sort — missing blob, torn write, different
    backend, an executable serialized under an incompatible device
    topology, an older jax — degrades to a miss and the caller compiles
    normally (then re-persists, healing the store).
    """
    if not active():
        return None
    key = _exec_key(sim, b, devices)
    try:
        with open(_exec_path(key), "rb") as fh:
            payload = fh.read()
        from jax.experimental import serialize_executable as _se
        blob, in_tree, out_tree = pickle.loads(payload)
        fn = _se.deserialize_and_load(blob, in_tree, out_tree)
    except Exception:
        _note("exec", key, False)
        return None
    try:
        os.utime(_exec_path(key))     # LRU recency: a hit is a touch
    except OSError:
        pass
    _note("exec", key, True, len(payload))
    return fn


def put_executable(sim, b: int, devices: int, compiled) -> None:
    """Serialize an AOT-compiled batched executable into the cache dir
    (atomic write; silently skipped when serialization is unsupported)."""
    if not active():
        return
    key = _exec_key(sim, b, devices)
    try:
        from jax.experimental import serialize_executable as _se
        payload = pickle.dumps(_se.serialize(compiled))
    except Exception:                  # pragma: no cover - jax internals
        return
    d = cache_dir()
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".dse_exec_")
    try:
        with os.fdopen(fd, "wb") as fh:
            fh.write(payload)
        os.replace(tmp, _exec_path(key))
    except OSError:                    # read-only dir: skip persistence
        try:
            os.unlink(tmp)
        except OSError:
            pass
        return
    _counts["writes"] += 1
    if BUS.active:
        BUS.emit("cache.write", what="exec", key=key, bytes=len(payload))
        BUS.count("dse.cache.writes")
    gc()          # keep the dir under the size cap as it grows
