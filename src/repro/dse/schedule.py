"""Round scheduling for straggler-free batched sweeps (DSE.md).

A monolithic vmapped batch runs every lane until the *slowest* lane's
horizon: finished lanes keep burning full masked epochs, so at B=256 the
batch can fall below sequential shared-jit throughput.  The runner breaks
a sweep into *rounds* instead — run a bounded epoch quantum, pull the
cheap per-lane liveness vector to host, compact the surviving lanes into
the next rung of a geometric **chunk ladder** and refill from the
pending-config queue.  This module owns the policy side of that loop:

* :func:`make_ladder` — the descending geometric rung sizes.  Every rung
  compiles once (executables are cached per batch size), so arbitrary B
  streams through a handful of cached programs with zero recompiles
  after warmup.
* :class:`ChunkSchedule` — ladder + epoch quantum + autotune switches.
  The quantum is *adaptive upward*: when a round's total cost — device
  wall time **plus the host-side harvest/compact/assembly time the
  runner reports** — falls under ``min_round_s``, or when host work
  dominates the device step outright, the quantum doubles (bounded), so
  round overhead stays amortized on any workload without retuning.
  Counting host time matters under pipelining (ENGINE_PERF.md "Round
  pipelining"): a short quantum used to look free because only the
  device step was credited, even when per-round host bookkeeping was
  the actual bottleneck.  Quantum and ladder choices never change
  results — lanes are independent under vmap and freeze bit-exactly at
  their own horizons — they only move wall-clock.
* :class:`ChunkAutotuner` — a one-shot probe of 2–3 ladder rungs on the
  first quanta, picking the rung with the best measured lane throughput.
  The probe score divides lanes by device time *plus* that round's
  host-side harvest/compact time, so the winner maximizes pipeline
  occupancy — end-to-end round throughput — not just device throughput
  (a wide rung whose harvest gathers dominate the round no longer wins).
  On small hosts the config-axis vmap saturates well below large B
  (DSE.md "Performance"), so the right chunk is often much smaller than
  the sweep; probing is real work (probe lanes advance normally), so it
  costs only the timing, not replayed simulation.
"""
from __future__ import annotations

import dataclasses

MIN_RUNG = 8          # smallest ladder rung worth its own executable
MAX_TOP = 256         # default ladder top (probe downward from here)
DEFAULT_QUANTUM = 128         # epochs per round before a liveness pull
MAX_QUANTUM = 1 << 20
AUTOTUNE_MIN_B = 64   # below this, probing costs more than it saves


def make_ladder(b: int, top: int | None = None, min_rung: int = MIN_RUNG,
                factor: int = 2) -> tuple[int, ...]:
    """Descending geometric rung sizes for a B-point sweep.

    The top rung is ``min(b, top)`` (default ``MAX_TOP``); below it the
    sizes divide by ``factor`` down to ``min_rung``.  Rungs never exceed
    ``b`` — a 5-point sweep gets the single rung ``(5,)``.  ``top`` /
    ``min_rung`` values below 1 clamp to 1 (a zero or negative chunk
    request degenerates to lane-at-a-time, it never hangs or raises).
    """
    assert b >= 1 and factor >= 2
    t = max(1, min(b, MAX_TOP if top is None else int(top)))
    mr = max(1, min(int(min_rung), t))
    rungs = [t]
    while rungs[-1] // factor >= mr:
        rungs.append(rungs[-1] // factor)
    return tuple(rungs)


@dataclasses.dataclass
class ChunkSchedule:
    """Ladder, quantum and autotune policy for one round-based run.

    ``ladder`` — descending chunk sizes; each rung that gets used
    compiles one executable (cached on the runner).  ``quantum`` —
    engine epochs each lane may advance per round; adaptively doubled
    while rounds finish faster than ``min_round_s`` so host-side round
    overhead stays negligible.  ``autotune`` — probe the top
    ``probe_rungs`` rungs on the first quanta and keep the fastest
    (:class:`ChunkAutotuner`); the choice is cached per runner so later
    calls (and the timed leg of a benchmark) skip the probe.
    """

    ladder: tuple[int, ...]
    quantum: int = DEFAULT_QUANTUM
    autotune: bool = False
    probe_rungs: int = 3
    min_round_s: float = 0.05

    def __post_init__(self):
        assert self.ladder and list(self.ladder) == sorted(
            self.ladder, reverse=True), "ladder must be descending"
        self.quantum = int(self.quantum)

    @property
    def top(self) -> int:
        return self.ladder[0]

    def size_for(self, want: int) -> int:
        """Smallest rung that fits ``want`` lanes (the top rung if none
        does) — survivors compact down the ladder as the sweep drains."""
        fit = [r for r in self.ladder if r >= want]
        return fit[-1] if fit else self.top

    def narrowed(self, top: int) -> "ChunkSchedule":
        """This schedule with the ladder trimmed to ``top`` (the
        autotuner's winning rung) and probing switched off."""
        ladder = tuple(r for r in self.ladder if r <= top) or (top,)
        return dataclasses.replace(self, ladder=ladder, autotune=False)

    def grow_quantum(self, round_dt: float, host_dt: float = 0.0,
                     steps: int = 1) -> None:
        """Adaptive quantum policy: grow while rounds are cheap *in
        total* — device step plus the host-side harvest/compact/assembly
        time (``host_dt``) the runner measured for the round — or while
        host work dominates the device step (then a bigger quantum
        amortizes the fixed per-round bookkeeping and raises pipeline
        occupancy).  ``steps`` bounds the doublings per observation and
        is the caller's pipeline depth: with depth d, d rounds are
        dispatched at a stale quantum before the next measurement
        arrives, so a double-per-observation ramp would pay every
        intermediate quantum d times over — d doublings per observation
        keeps the ramp's *round count* equal to the sequential loop's.
        Growth past the first doubling is predictive (the device step
        scales ~linearly with the quantum while the host side barely
        does, so the measured round is extrapolated before each extra
        doubling); at ``steps=1`` the policy is exactly the sequential
        one-doubling-per-cheap-round rule."""
        for _ in range(max(1, int(steps))):
            if self.quantum >= MAX_QUANTUM or not (
                    (round_dt + host_dt) < self.min_round_s
                    or host_dt > round_dt):
                return
            self.quantum *= 2
            round_dt *= 2.0


def auto_schedule(b: int, quantum: int | None = None,
                  chunk: int | None = None,
                  autotune: bool | None = None) -> ChunkSchedule:
    """The default policy for a B-point sweep.

    ``chunk`` pins the ladder top (no probing); otherwise sweeps big
    enough to amortize a probe (``b >= AUTOTUNE_MIN_B``) autotune the
    top rung, small ones just run at ``b``.
    """
    if chunk is not None:
        return ChunkSchedule(make_ladder(b, top=int(chunk)),
                             quantum=quantum or DEFAULT_QUANTUM)
    tune = (b >= AUTOTUNE_MIN_B) if autotune is None else autotune
    return ChunkSchedule(make_ladder(b), quantum=quantum or DEFAULT_QUANTUM,
                         autotune=tune)


class ChunkAutotuner:
    """One-shot rung probe: measure lane throughput at 2–3 rung sizes,
    keep the best.

    For each candidate rung the runner executes two rounds at that size:
    the first is the compile/warmup round (untimed), the second is timed.
    ``lanes / (dt + host_dt)`` at a fixed quantum is directly
    proportional to end-to-end configs/sec for uniform lanes — the
    denominator is the round's *total* cost, device step plus the
    host-side harvest/compact work the rung caused, so the winner is the
    rung with the best pipeline occupancy rather than the widest device
    dispatch.  Every probed round is *real* sweep progress — survivors
    flow back into the normal round loop — so the probe's only cost is
    running briefly at a sub-optimal width.
    """

    def __init__(self, schedule: ChunkSchedule, fillable: int):
        # probe the largest rungs first; a rung is only probeable while
        # enough live lanes (pool survivors + pending queue) can fill it
        self.candidates = [r for r in schedule.ladder[:schedule.probe_rungs]
                           if r <= fillable]
        self.rates: dict[int, float] = {}
        self._warmed: set[int] = set()

    def next_probe(self, fillable: int) -> int | None:
        """The rung to run the next probe round at, or ``None`` when
        probing is done (all candidates measured or starved of lanes).
        ``fillable`` counts every lane that could fill the rung — pool
        survivors *plus* the pending queue (round one typically drains
        the queue into lanes, so survivors must count or every rung
        below the top starves unprobed)."""
        for r in self.candidates:
            if r not in self.rates and (r in self._warmed or r <= fillable):
                return r
        return None

    def record(self, rung: int, dt: float, lanes: int | None = None,
               host_dt: float = 0.0) -> None:
        """Record a probe round.  ``lanes`` is the number of *live* lanes
        the round ran (zero-horizon padding executes no epochs and must
        not be credited as throughput); ``host_dt`` is the host-side
        harvest/compact/assembly time the round cost — part of the score,
        so a rung that is fast on device but expensive to compact does
        not win."""
        if rung in self._warmed:
            self.rates[rung] = (rung if lanes is None else lanes) \
                / max(dt + host_dt, 1e-9)
        else:
            self._warmed.add(rung)   # first (compile) round is untimed

    def best(self, default: int) -> int:
        if not self.rates:
            return default
        return max(self.rates, key=lambda r: self.rates[r])
