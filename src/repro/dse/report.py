"""Sweep result reporting: tidy tables, Pareto fronts, JSON/CSV export.

Rows are plain dicts (one per design point, axes merged with extracted
stats — the output of ``runner.run_sweep``), so everything here is
host-side bookkeeping over scalars.
"""
from __future__ import annotations

import csv
import json
from typing import Iterable, Mapping, Sequence

MIN, MAX = "min", "max"


def _as_scalar(v):
    try:
        f = float(v)
        return int(f) if f.is_integer() else f
    except (TypeError, ValueError):
        return v


def tidy(rows: Iterable[Mapping]) -> list[dict]:
    """Normalize rows: plain python scalars, union of keys, stable order."""
    rows = [dict(r) for r in rows]
    keys: list[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    return [{k: _as_scalar(r.get(k)) for k in keys} for r in rows]


def score_vector(row: Mapping, objectives: Mapping[str, str]) -> tuple:
    """Canonical "higher is better" objective vector of one row."""
    return tuple((1.0 if d == MAX else -1.0) * float(row[c])
                 for c, d in objectives.items())


def _dominates_scores(a: tuple, b: tuple) -> bool:
    """``a`` dominates ``b`` on canonical higher-is-better vectors."""
    return (all(x >= y for x, y in zip(a, b))
            and any(x > y for x, y in zip(a, b)))


def dominates(a: Mapping, b: Mapping,
              objectives: Mapping[str, str]) -> bool:
    """Whether row ``a`` dominates row ``b`` under ``objectives``
    ({column: 'min'|'max'}): at least as good on every objective and
    strictly better on one.  NaN objectives dominate nothing and are
    dominated by nothing (NaN compares false), matching
    :func:`pareto_front`'s exclusion rule.  Shared by the front
    extraction below and the search promoters
    (:mod:`repro.dse.search`)."""
    return _dominates_scores(score_vector(a, objectives),
                             score_vector(b, objectives))


def pareto_front(rows: Sequence[Mapping],
                 objectives: Mapping[str, str]) -> list[dict]:
    """Non-dominated rows under ``objectives`` ({column: 'min'|'max'}).

    A row is dominated when some other row is at least as good on every
    objective and strictly better on one.  Ties/duplicates keep the first
    occurrence.  Rows are returned in input order.  Rows with a NaN in
    any objective are excluded — NaN compares false against everything,
    so they could neither dominate nor be dominated and would otherwise
    pollute every front (a NaN metric usually means the config never
    finished; it is not a trade-off point).

    Sort-based fast path: candidates are visited in descending
    lexicographic score order, in which a dominator always precedes
    everything it dominates — so each candidate is checked against the
    current front only (O(n·|front| + n log n), not all-pairs O(n²)).
    """
    assert objectives and all(d in (MIN, MAX) for d in objectives.values())

    scored = [(s, i) for i, r in enumerate(rows)
              for s in [score_vector(r, objectives)]
              if not any(v != v for v in s)]
    # descending lex by score; ties resolved to input order so the first
    # occurrence of a duplicate vector is the one visited (and kept)
    order = sorted(range(len(scored)),
                   key=lambda k: (tuple(-v for v in scored[k][0]),
                                  scored[k][1]))
    front_scores: list[tuple] = []
    front_idx: list[int] = []
    seen: set[tuple] = set()
    for k in order:
        s, i = scored[k]
        if s in seen:
            continue
        if not any(_dominates_scores(fs, s) for fs in front_scores):
            front_scores.append(s)
            front_idx.append(i)
            seen.add(s)
    return [dict(rows[i]) for i in sorted(front_idx)]


def to_json(rows: Iterable[Mapping], path: str) -> None:
    with open(path, "w") as fh:
        json.dump(tidy(rows), fh, indent=2, sort_keys=True)
        fh.write("\n")


def to_csv(rows: Iterable[Mapping], path: str) -> None:
    rows = tidy(rows)
    if not rows:
        open(path, "w").close()
        return
    with open(path, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)


def format_table(rows: Sequence[Mapping], floatfmt: str = "{:.4g}") -> str:
    """Fixed-width text table (for example scripts / logs)."""
    rows = tidy(rows)
    if not rows:
        return "(no rows)"
    cols = list(rows[0])
    cells = [[c for c in cols]]
    for r in rows:
        cells.append([
            floatfmt.format(r[c]) if isinstance(r[c], float) else str(r[c])
            for c in cols])
    widths = [max(len(row[j]) for row in cells) for j in range(len(cols))]
    lines = ["  ".join(c.rjust(w) for c, w in zip(row, widths))
             for row in cells]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)
