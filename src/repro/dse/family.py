"""Topology families: one padded build serving every sub-shape by mask.

A *topology family* replaces one-build-per-shape in structural sweeps:
the simulation is built once at the family's **maximum shape**
(``SimBuilder.build(pad_shape=...)`` sizes every kind's segments to the
maximum), and each concrete shape is selected at run time by the traced
``SimParams.inst_mask`` / ``conn_mask`` activity masks — so a 1..8-core
grid is one compile + one vmapped run instead of one compile group per
``static.*`` shape (DSE.md "Topology families").

:class:`TopologyFamily` is the contract between a model's family-aware
builder (``repro.sims.memsys.build_family`` /
``repro.sims.onira.build_onira_family``) and the sweep runner:

* ``kind_counts(shape)`` maps the model's shape axes (e.g. ``core=4``)
  to per-kind active instance counts for the engine's prefix masks;
* ``state_fn(shape)`` builds the padded initial ``SimState`` whose
  *active rows are bit-identical* to an unpadded build of that shape
  (masked rows are inert and pinned to ``next_tick = +inf``);
* ``params_for(shape, ...)`` attaches the masks to a ``SimParams``.

The masks act in the hot loop through broadcast ``&``/``where`` selects
only — never as gather/scatter indices — so the scatter-free property
(ENGINE_PERF.md) survives shape batching; pinned by
``tests/dse/test_scatter_free.py`` on the optimized HLO.

Masked lanes compose with *per-lane horizons* (runner/DSE.md "Rounds
and the chunk ladder") with no family-side work: a masked instance is
pinned to ``next_tick = +inf``, so it simply never contributes to the
next-event min that decides when the lane reaches its own ``until`` —
mixed sub-shapes at mixed horizons ride the same round/compaction loop
(pinned bit-identical by ``tests/dse/test_rounds.py``, scatter-free on
the masked per-lane-horizon HLO by ``test_scatter_free.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.core import SimParams, SimState, Simulation

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class TopologyFamily:
    """A padded maximum-shape build plus per-shape state/mask factories.

    ``shape_max`` names the family's shape axes and their maxima (the
    shape the topology was built at); ``kind_counts`` translates a shape
    assignment into per-kind active counts (model-specific — e.g. memsys
    maps ``core=n`` to n cores + n L1s + the one shared DRAM); ``state_fn``
    builds the padded initial state for a shape.  Shape assignments may be
    partial: missing axes default to the family maximum.
    """

    sim: Simulation
    shape_max: dict[str, int]
    kind_counts: Callable[[dict], dict]
    state_fn: Callable[[dict], SimState]

    def full_shape(self, shape: dict | None = None) -> dict:
        shape = dict(shape or {})
        unknown = set(shape) - set(self.shape_max)
        if unknown:
            raise ValueError(
                f"unknown shape axes {sorted(unknown)} "
                f"(family axes: {sorted(self.shape_max)})")
        for name, mx in self.shape_max.items():
            v = int(shape.get(name, mx))
            if not 1 <= v <= mx:
                raise ValueError(
                    f"shape.{name}={v} outside this family's range "
                    f"[1, {mx}]")
            shape[name] = v
        return shape

    def masks(self, shape: dict | None = None):
        """``(inst_mask, conn_mask)`` prefix activity masks for a shape."""
        return self.sim.prefix_masks(self.kind_counts(self.full_shape(shape)))

    def params_for(self, shape: dict | None = None,
                   base: SimParams | None = None,
                   masks: tuple | None = None) -> SimParams:
        """``base`` (default: the build-time params) with the shape's
        activity masks attached.  ``masks`` short-circuits the mask
        derivation when the caller already holds ``self.masks(shape)``
        (the runner memoizes them per distinct shape)."""
        base = self.sim.default_params() if base is None else base
        inst, conn = self.masks(shape) if masks is None else masks
        return dataclasses.replace(base, inst_mask=inst, conn_mask=conn)

    def state_for(self, shape: dict | None = None,
                  masks: tuple | None = None) -> SimState:
        """Padded initial state for a shape: the model's ``state_fn``
        output with masked-off rows pinned to ``next_tick = +inf`` (so
        they never enter the engine's next-event min, and the epoch
        sequence matches an unpadded build even before the first tick)."""
        shape = self.full_shape(shape)
        st = self.state_fn(shape)
        inst, _ = self.masks(shape) if masks is None else masks
        alive = self.sim._flat_inst_mask(inst)
        return dataclasses.replace(
            st, next_tick=jnp.where(alive, st.next_tick, INF))
