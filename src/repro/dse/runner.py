"""Batched design-space execution: vmap the engine's fused hot loop over a
stacked :class:`~repro.core.SimParams` batch.

One jitted program simulates every design point of a topology at once:
``jax.vmap`` maps the ``while_loop`` body over the config axis.  The
horizon and epoch budget are *traced per-lane operands* — each lane
freezes bit-exactly at its own ``until`` / ``max_epochs`` (the batching
rule selects the old carry for finished lanes), so a B=1 batch is
*bit-identical* to the unbatched engine and mixed-horizon lanes are
first-class.  Params enter the loop as broadcast operands only, so the
scatter-free hot-loop property (ENGINE_PERF.md) survives batching.

Execution strategies, cheapest lane-waste first:

* **Rounds** (``run_rounds``, what ``run_sweep`` uses) — the
  straggler-free path: run a bounded epoch *quantum*, pull the cheap
  per-lane liveness vector to host, drop finished lanes, compact the
  survivors (a device gather outside the jitted loop) into a rung of the
  geometric **chunk ladder** (``repro.dse.schedule``) and refill from the
  pending-config queue.  A monolithic batch runs every lane to the
  *slowest* lane's horizon — finished lanes burn full masked epochs — and
  large B can fall below sequential shared-jit throughput; rounds stream
  arbitrary B through a handful of cached executables (one per rung, zero
  recompiles after warmup) at the autotuned batch width.  The loop is a
  depth-2 software pipeline by default: round *k+1* is assembled and
  dispatched while round *k* computes on device and its liveness copy
  streams to host asynchronously, so host bookkeeping overlaps device
  work (ENGINE_PERF.md "Round pipelining"; ``pipeline=False`` restores
  the strictly alternating loop, bit-identically).
* **Chunking** — ``run_chunked(chunk=...)`` splits B into fixed-size
  slabs (no mid-run compaction); the final partial slab is padded with
  *zero-horizon* lanes that freeze on entry instead of re-simulating the
  repeated tail point.
* **Sharding** — ``shard=True`` (or ``shard=<n devices>``) lays the
  batch out as ``[shards, chunk]`` lanes over an explicit 1-D device
  mesh (``core.pdes.lane_mesh``) and runs **one** ``shard_map``-of-vmap
  executable across the whole mesh per round; with one device this is
  the plain vmap path and results are bit-identical either way (lanes
  are independent — a mesh only changes where each lane's arithmetic
  runs).  Batches that don't divide the device count are padded with
  zero-horizon lanes (freeze on entry) instead of shrinking to a
  divisor, so every device stays busy at any B.  Under ``run_rounds``
  the harvest/compact/refill step is *global*: survivors from all
  shards pool on the host and re-pack across shards each round, so a
  shard that drains early picks up its neighbours' pending lanes
  instead of idling (``shard.rebalance`` telemetry counts the moves).

Cold-start cost is covered by ``repro.dse.cache`` (DSE.md "Sharded
sweeps and the persistent cache"): ``run_sweep`` enables the jax
persistent compilation cache on entry when a cache dir is configured,
and the runner persists its own warm-start artifacts (autotuned rung,
warm-ladder rung set, family shape unions) so a fresh process repeats a
previous process's executable requests exactly.
* **Donation** — batched states are donated into the loop exactly like
  the unbatched engine (build knob ``donate=``); ``stack_states``
  materializes fresh per-lane copies so no lane aliases another lane or
  the template state (donating an aliased batch would corrupt sibling
  configs).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import inspect
import time
import weakref
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.core import SimParams, SimState, check_not_consumed
from repro.core.pdes import LANE_AXIS, _SM_KW, lane_mesh, shard_map_compat
from repro.obs.bus import BUS

from . import cache as dse_cache
from .family import TopologyFamily
from .schedule import ChunkSchedule, ChunkAutotuner, auto_schedule
from .sweep import (STATIC_PREFIX, SweepSpec, apply_point,
                    build_param_batch, split_shape, stack_params,
                    stack_trees)

INT32_MAX = np.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class ResumeHandle:
    """A frozen lane's continuation point: the final :class:`SimState` of
    a finished run plus where it stopped.

    The engine's horizon is an absolute traced operand and its epoch
    sequence is purely state-determined, so feeding ``state`` back in as
    a lane's initial state and running to a *longer* ``until`` continues
    bit-exactly where the run froze — the warm-promotion contract of
    ``repro.dse.search`` (a resumed lane equals a cold run to the same
    horizon, pinned by ``tests/dse/test_warm_resume.py``).  ``time`` and
    ``epochs`` let budget accounting charge only the increment and the
    round loop cap epochs correctly from the first round.
    """

    state: SimState
    time: float        # frozen virtual_time
    until: float       # horizon the state was run to
    epochs: int        # engine epochs executed so far


class LaneStates:
    """Lazy per-point access to the final states of a finished sweep.

    ``run_sweep(return_states=True)`` hands every group's stacked final
    state to one of these, reusing the single host transfer the row
    extraction already paid — no extra device syncs.  Only the lanes a
    caller actually asks for are sliced (a halving search touches the
    survivors, not the whole rung).  ``handle(i, until)`` packages lane
    ``i`` as a :class:`ResumeHandle` for a later warm resume.
    """

    def __init__(self):
        self._groups: list = []            # host-side stacked trees
        self._where: dict[int, tuple[int, int]] = {}

    def add_group(self, host_tree, indices: Sequence[int]) -> None:
        g = len(self._groups)
        self._groups.append(host_tree)
        for j, i in enumerate(indices):
            self._where[int(i)] = (g, j)

    def __contains__(self, i) -> bool:
        return int(i) in self._where

    def __len__(self) -> int:
        return len(self._where)

    def state(self, i: int) -> SimState:
        g, j = self._where[int(i)]
        return lane(self._groups[g], j)

    def time(self, i: int) -> float:
        g, j = self._where[int(i)]
        return float(self._groups[g].time[j])

    def epochs(self, i: int) -> int:
        g, j = self._where[int(i)]
        return int(self._groups[g].stats.epochs[j])

    def handle(self, i: int, until: float) -> ResumeHandle:
        return ResumeHandle(state=self.state(i), time=self.time(i),
                            until=float(until), epochs=self.epochs(i))


def stack_states(state: SimState, n: int) -> SimState:
    """``n`` independent copies of ``state`` stacked on a new leading axis.

    ``jnp.stack`` materializes one fresh buffer per leaf — lanes never
    alias each other or the input, so the result is safe to donate while
    ``state`` stays reusable as a template.
    """
    return jax.tree.map(lambda x: jnp.stack([x] * n), state)


def stack_state_list(states: Sequence[SimState]) -> SimState:
    """Stack *distinct* per-lane states (e.g. one per family sub-shape)
    into a batch.  Fresh buffers per leaf, like :func:`stack_states`."""
    return stack_trees(states)


def lane(tree, i: int):
    """Extract config ``i``'s slice from a batched pytree (device- or
    host-side — works on jax arrays and on the numpy tree a single
    ``jax.device_get`` returns)."""
    return jax.tree.map(lambda x: x[i], tree)


def default_extract(sim, s: SimState) -> dict:
    """Per-config scalar results: virtual time + engine counters.

    ``run_sweep`` hands this *host-side* lanes (one ``jax.device_get``
    of the whole chunk, sliced on host), so the ``float()``/``int()``
    casts below are free; on a raw device lane each cast would be its
    own device→host sync.
    """
    return {
        "virtual_time": float(s.time),
        "epochs": int(s.stats.epochs),
        "ticks": int(s.stats.ticks),
        "progress_ticks": int(s.stats.progress_ticks),
        "delivered": int(s.stats.delivered),
    }


def extract_rows(sim, out_b: SimState, n: int,
                 extract: Callable | None = None) -> list[dict]:
    """Extract ``n`` result rows from a batched final state with a single
    device→host transfer.

    One ``jax.device_get`` pulls the whole stacked tree at once; lanes
    are then sliced on host, so an extractor touching k scalar fields
    costs 1 transfer total instead of ``n * k`` syncs.
    """
    extract = extract or default_extract
    host = jax.device_get(out_b)
    return [extract(sim, lane(host, j)) for j in range(n)]


def _vec(x, b: int, dtype) -> jax.Array:
    """Broadcast a scalar-or-per-lane operand to a strong-typed [b]
    vector (one dtype/shape signature per batch size => no retraces)."""
    a = np.broadcast_to(np.asarray(x, dtype), (b,))
    return jnp.asarray(np.ascontiguousarray(a))


def _shard_devices(shard) -> int:
    """Normalize a ``shard`` argument (bool or device count) to the
    number of mesh devices to span: ``False``/``0`` → 1 (plain vmap),
    ``True`` → every local device, an int → that many (clamped to what
    the host actually has, never below 1)."""
    if shard is True:
        return jax.local_device_count()
    if not shard:
        return 1
    return max(1, min(int(shard), jax.local_device_count()))


def _align_up(n: int, d: int) -> int:
    """``n`` rounded up to a multiple of ``d``."""
    return -(-int(n) // int(d)) * int(d)


def _horizons(until, max_epochs, b: int) -> tuple[np.ndarray, np.ndarray]:
    """Normalize scalar-or-per-lane horizons to host vectors: [b] f32
    ``until`` and [b] i32 ``max_epochs`` (budgets beyond int32 clamp —
    the engine's epoch counter is i32, so the clamp is exact)."""
    u = np.broadcast_to(np.asarray(until, np.float32), (b,)) \
        .astype(np.float32)
    m = np.broadcast_to(
        np.minimum(np.asarray(max_epochs, np.int64), INT32_MAX)
        .astype(np.int32), (b,)).astype(np.int32)
    return u, m


class BatchRunner:
    """Compiled batched runs over one :class:`Simulation`'s design space.

    Jitted executables are cached per (batch size, shard topology) — the
    horizon and epoch budget are traced per-lane operands, so neither
    ``until`` nor ``max_epochs`` keys the cache and chunk-ladder rounds
    never recompile after warmup.  ``trace_count`` counts actual
    retraces (each jit compile runs the wrapped python once) and is
    pinned by ``tests/dse/test_rounds.py``.
    """

    def __init__(self, sim):
        self.sim = sim
        self._fns: dict[tuple, Callable] = {}
        self.trace_count = 0          # python re-traces == XLA compiles
        # devices -> autotuned rung: the winning chunk depends on the
        # shard topology (per-device width is C/d), so a runner reused
        # under a different mesh must not inherit a stale rung
        self._tuned_top: dict[int, int] = {}
        self.last_rounds: dict | None = None    # diagnostics of last run
        self.last_shard = 1           # devices the last run_batch spanned

    # ------------------------------------------------------------------
    def _batched_fn(self, b: int, d: int):
        """The compiled batched run for batch size ``b`` spanning ``d``
        mesh devices.  ``d == 1`` is the plain jitted vmap; ``d > 1``
        wraps the same vmap in ``shard_map`` over the shared lane mesh
        (``core.pdes.lane_mesh``) — lanes lay out as ``[d, b/d]``, one
        executable runs across the whole mesh, and because lanes are
        independent under vmap the rows are bit-identical to the
        single-device path.  ``b`` must be a multiple of ``d`` (callers
        pad with zero-horizon lanes — see :meth:`run_batch`)."""
        key = (b, d)
        fn = self._fns.get(key)
        if fn is not None:
            return fn
        sim = self.sim
        if dse_cache.active():
            # whole-executable rehydrate: the persisted binary skips
            # trace + lower + compile entirely (bit-identical results —
            # it IS the executable a fresh compile would produce)
            loaded = dse_cache.get_executable(sim, b, d)
            if loaded is not None:
                self._fns[key] = loaded
                return loaded

        def one(s, p, u, m):
            self.trace_count += 1     # runs only while (re)tracing
            return sim._run(s, u, m, params=p)

        vm = jax.vmap(one, in_axes=(0, 0, 0, 0))
        if d > 1:
            assert b % d == 0, (b, d)
            # one program over the whole mesh: each device traces the
            # same vmap over its b/d local lanes (SPMD — the DSE config
            # axis is embarrassingly parallel, so no collectives)
            sm = shard_map_compat(
                vm, mesh=lane_mesh(d),
                in_specs=(P(LANE_AXIS),) * 4, out_specs=P(LANE_AXIS),
                **_SM_KW)
            fn = jax.jit(sm, donate_argnums=(0,) if sim.donate else ())
        else:
            fn = jax.jit(
                vm, donate_argnums=(0,) if sim.donate else ())
        if dse_cache.active():
            target, runner = fn, self

            def fn(s, p, u, m):
                # AOT on first call so the compiled object is in hand
                # to persist; lowering runs the python (same trace as
                # the lazy jit path — trace_count telemetry holds)
                compiled = target.lower(s, p, u, m).compile()
                dse_cache.put_executable(sim, b, d, compiled)
                runner._fns[key] = compiled
                return compiled(s, p, u, m)
        self._fns[key] = fn
        return fn

    def _liveness_start(self, out_b: SimState, u_vec, budget_vec):
        """Dispatch the per-lane ``(live, epochs)`` liveness program on a
        batched state and *start* its device→host copy asynchronously
        (``copy_to_host_async``) — the round loop's non-blocking half.
        ``live`` means the lane still has events before its horizon and
        epoch budget — the compaction key.  Returns an opaque pending
        handle for :meth:`_liveness_read`; nothing here blocks on the
        device, so the caller can keep dispatching (the next round's
        step) while the transfer drains in the background."""
        b = int(out_b.time.shape[0])
        key = ("live", b)
        fn = self._fns.get(key)
        if fn is None:
            sim = self.sim

            def one(s, u, m):
                self.trace_count += 1
                return sim._live(s, u, m), s.stats.epochs

            fn = jax.jit(jax.vmap(one))
            self._fns[key] = fn
        tc0 = self.trace_count
        t0 = time.perf_counter()
        live, ep = fn(out_b, _vec(u_vec, b, np.float32),
                      _vec(budget_vec, b, np.int32))
        if BUS.active and self.trace_count > tc0:
            BUS.emit("compile", what="liveness", b=b,
                     n=self.trace_count - tc0,
                     dur=time.perf_counter() - t0)
        for a in (live, ep):
            try:
                a.copy_to_host_async()
            except AttributeError:    # older jax array types: sync get
                pass
        return (live, ep, b)

    def _liveness_read(self, pending):
        """Blocking half of the liveness pull: materialize the vectors a
        :meth:`_liveness_start` call put in flight.  Returns
        ``((live, epochs), wait_s)`` — ``wait_s`` is the time spent
        blocked here, which under pipelining is (near) zero because the
        transfer ran while the host did round *k+1*'s work."""
        live, ep, b = pending
        t0 = time.perf_counter()
        out = jax.device_get((live, ep))
        dt = time.perf_counter() - t0
        if BUS.active:
            BUS.emit("transfer", what="liveness", b=b, dur=dt)
            BUS.observe("dse.transfer.liveness_s", dt)
        return out, dt

    def _liveness(self, out_b: SimState, u_vec, budget_vec):
        """Dispatch + block: the one-shot liveness pull (warm-ladder and
        compatibility callers; the round loop uses the split halves)."""
        out, _ = self._liveness_read(
            self._liveness_start(out_b, u_vec, budget_vec))
        return out

    # ------------------------------------------------------------------
    def run_batch(self, states_b: SimState, params_b: SimParams,
                  until, max_epochs=2_000_000,
                  shard: "bool | int" = False) -> SimState:
        """One vmapped jitted run of a pre-stacked batch.

        ``until`` and ``max_epochs`` may be scalars (shared by every
        lane) or per-lane vectors of length B — each lane freezes
        bit-exactly at its own horizon / budget (stragglers excepted,
        the loop still *iterates* until the slowest lane is done; use
        :meth:`run_rounds` to reclaim that waste).

        ``shard`` spans the lane mesh: ``True`` means every local
        device, an int pins the count.  A batch that doesn't divide the
        device count is padded to the next multiple by repeating the
        last lane at **zero horizon and zero budget** (it freezes on
        entry, exactly like chunk padding) and the padding rows are
        sliced off the result — every device runs ``ceil(B/d)`` lanes
        instead of silently falling back to a divisor of B.

        ``states_b`` is donated when the simulation was built with
        ``donate=True`` — treat it as consumed (see ``stack_states`` /
        ``Simulation.copy_state``); reusing a consumed batch raises
        immediately instead of failing deep inside XLA dispatch.
        """
        if self.sim.donate:
            check_not_consumed(states_b)
        b = int(params_b.conn_latency.shape[0])
        d = _shard_devices(shard)
        self.last_shard = d
        u, m = _horizons(until, max_epochs, b)
        pad = _align_up(b, d) - b
        if pad:
            grow = lambda x: jnp.concatenate([x] + [x[-1:]] * pad)
            states_b = jax.tree.map(grow, states_b)
            params_b = jax.tree.map(grow, params_b)
            u = np.concatenate([u, np.zeros(pad, np.float32)])
            m = np.concatenate([m, np.zeros(pad, np.int32)])
        fn = self._batched_fn(b + pad, d)
        trim = (lambda o: jax.tree.map(lambda x: x[:b], o)) if pad \
            else (lambda o: o)
        if not BUS.active:
            return trim(fn(states_b, params_b, jnp.asarray(u),
                           jnp.asarray(m)))
        # telemetry: a trace_count bump across this (host-side) dispatch
        # means XLA traced+compiled a fresh executable inside the call
        tc0 = self.trace_count
        t0 = time.perf_counter()
        out = fn(states_b, params_b, jnp.asarray(u), jnp.asarray(m))
        if self.trace_count > tc0:
            BUS.emit("compile", what="run", b=b + pad, shard=d,
                     n=self.trace_count - tc0,
                     dur=time.perf_counter() - t0)
            BUS.count("dse.compiles", self.trace_count - tc0)
        return trim(out)

    # ------------------------------------------------------------------
    def run_chunked(self, template: SimState | Sequence[SimState],
                    params_b: SimParams, until,
                    chunk: int | None = None,
                    max_epochs=2_000_000,
                    shard: "bool | int" = False) -> SimState:
        """Run a B-point batch in fixed-size chunks of fresh state stacks.

        ``template`` is either one ``SimState`` (every lane starts from a
        fresh copy of it) or a sequence of B per-lane states (topology
        families: each lane's initial state encodes its sub-shape's
        workload).  ``until`` / ``max_epochs`` may be per-lane vectors.
        All chunks share one compiled executable; the final partial chunk
        is padded by repeating its last point with a **zero horizon and
        zero epoch budget** — padding lanes freeze on entry instead of
        re-simulating the tail point at full horizon — and the padding
        lanes are dropped from the result.  Returns the stacked final
        states in point order.
        """
        B = int(params_b.conn_latency.shape[0])
        per_lane = isinstance(template, (list, tuple))
        if per_lane:
            assert len(template) == B, (len(template), B)
        u, m = _horizons(until, max_epochs, B)
        chunk = B if chunk is None else max(1, min(int(chunk), B))
        outs = []
        for lo in range(0, B, chunk):
            hi = min(lo + chunk, B)
            part = jax.tree.map(lambda x: x[lo:hi], params_b)
            pad = chunk - (hi - lo)
            u_p, m_p = u[lo:hi], m[lo:hi]
            if pad:                   # repeat the last point's row shape,
                part = jax.tree.map(  # but freeze it: until=0, budget=0
                    lambda x: jnp.concatenate([x] + [x[-1:]] * pad), part)
                u_p = np.concatenate([u_p, np.zeros(pad, np.float32)])
                m_p = np.concatenate([m_p, np.zeros(pad, np.int32)])
            if per_lane:
                lanes = list(template[lo:hi])
                lanes += [lanes[-1]] * pad
                sb = stack_state_list(lanes)
            else:
                sb = stack_states(template, chunk)
            out = self.run_batch(sb, part, u_p, m_p, shard)
            if pad:
                out = jax.tree.map(lambda x: x[:hi - lo], out)
            outs.append(out)
        if len(outs) == 1:
            return outs[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs)

    # ------------------------------------------------------------------
    def warm_ladder(self, template: SimState | Sequence[SimState],
                    params_b: SimParams, sizes: Sequence[int],
                    shard: "bool | int" = False) -> None:
        """Compile the run + liveness executables for the given batch
        sizes without advancing any lane: a zero-horizon, zero-budget
        batch traces and compiles the full program but executes no
        epochs.  Benchmarks use this so a drain-phase rung can never
        compile inside a timed region."""
        t = template[0] if isinstance(template, (list, tuple)) else template
        if self.sim.donate:
            check_not_consumed(t)
        for b in sizes:
            # host-side row replication, not a device gather: warming
            # must request exactly the executables the round loop will
            # run — an extra tiny gather program here would miss (and so
            # pollute) the persistent compilation cache on warm starts
            pb = jax.tree.map(
                lambda x: jnp.asarray(
                    np.broadcast_to(np.asarray(x)[:1],
                                    (b,) + np.shape(x)[1:])), params_b)
            out = self.run_batch(stack_states(t, b), pb, 0.0, 0, shard)
            self._liveness(out, np.zeros(b, np.float32),
                           np.zeros(b, np.int32))

    # ------------------------------------------------------------------
    def run_rounds(self, template: SimState | Sequence[SimState],
                   params_b: SimParams, until,
                   schedule: ChunkSchedule | None = None,
                   max_epochs=2_000_000,
                   shard: "bool | int" = False,
                   init_epochs=None,
                   pipeline: "bool | int | None" = None) -> SimState:
        """Straggler-free streaming run: rounds + lane compaction + the
        chunk ladder (DSE.md "Rounds and the chunk ladder").

        Each round runs one epoch *quantum* of a ladder-sized batch,
        pulls the per-lane liveness vector to host (one tiny transfer),
        records finished lanes, compacts survivors (a device gather on
        the batch axis — outside the jitted loop, so the hot loop stays
        scatter-free) and refills from the pending-config queue.  Lanes
        are independent under vmap and freeze bit-exactly at their own
        horizons, so the result is **bit-identical** to a single
        full-batch :meth:`run_batch` at per-lane ``until`` — rounds only
        change wall-clock (pinned by ``tests/dse/test_rounds.py``).

        **Pipelining** (``pipeline``, default on — ENGINE_PERF.md "Round
        pipelining"): the loop is a depth-2 software pipeline.  Round
        *k+1* is assembled from the survivor pool and the pending queue
        and its device step + liveness program are *dispatched* before
        the host blocks on round *k*'s liveness — whose device→host
        copy was already started asynchronously at dispatch time
        (:meth:`_liveness_start`) — so device compute and host-side
        harvest/compact/refill overlap instead of alternating.  The two
        in-flight rounds are disjoint lane sets in independent
        donation-safe buffers (assembly always materializes fresh
        buffers), rotated every round; because lanes are independent and
        freeze bit-exactly at their own horizons, *which* round a lane
        rides in never changes its result — pipelined rows are
        bit-identical to the sequential loop's (pinned by
        ``tests/dse/test_pipeline.py``).  The host only synchronizes on
        a round when deciding its compaction — never to choose the next
        dispatch's executable shape, which is sized from the lanes
        already resolved.  ``pipeline=False`` (or ``1``) restores the
        strictly-alternating loop; an int sets the depth explicitly.
        Autotune probe rounds and the endgame run unpipelined (probes
        need clean per-round timings; the endgame needs every lane
        resolved).

        Under ``shard`` the round batch spans the lane mesh as
        ``[d, C/d]`` and the compact/refill step is **global**: the
        survivor pool is one host-side queue across all shards, so each
        round re-packs live lanes over the whole mesh and a shard whose
        lanes drained early picks up its neighbours' pending work
        instead of idling (the per-round ``shard.rebalance`` event
        counts lanes that changed shard).  Ladder rungs align up to
        multiples of ``d`` so every device runs the same lane count.

        ``schedule`` defaults to :func:`~repro.dse.schedule.auto_schedule`
        — with a one-shot chunk autotune for large B whose winning rung
        is cached on this runner (and, when a campaign cache dir is
        configured, persisted via ``repro.dse.cache`` keyed on the sim
        signature + shard topology, so a *fresh process* also skips the
        probe and asks for exactly the executables a previous process
        compiled).  Returns the stacked final states in point order.

        ``init_epochs`` (scalar or per-lane) is the epoch count already
        recorded in each lane's *initial* state — warm resumes pass the
        epochs a :class:`ResumeHandle` carries so the very first round's
        quantum cap advances from there instead of from zero (a cap
        below the state's own counter would execute an empty round; the
        liveness pull self-corrects, but only after a wasted dispatch).
        """
        B = int(params_b.conn_latency.shape[0])
        per_lane = isinstance(template, (list, tuple))
        if per_lane:
            assert len(template) == B, (len(template), B)
        if self.sim.donate:      # catch consumed templates up front, not
            for t in (template if per_lane else [template]):  # mid-round
                check_not_consumed(t)
        u, budget = _horizons(until, max_epochs, B)
        d = _shard_devices(shard)
        auto = schedule is None
        schedule = auto_schedule(B) if auto else \
            dataclasses.replace(schedule)              # never mutate input
        if d > 1:
            # align every rung up to a multiple of d — each round's batch
            # lays out as [d, C/d], and an unaligned rung would pad every
            # round; tuner/ladder bookkeeping all works in aligned units
            schedule = dataclasses.replace(
                schedule, ladder=tuple(sorted(
                    {_align_up(r, d) for r in schedule.ladder},
                    reverse=True)))
        if auto:
            tuned = self._tuned_top.get(d)
            if tuned is None:
                tuned = dse_cache.get_tuned_top(self.sim, d)
                if tuned is not None:   # a previous process's winner
                    self._tuned_top[d] = tuned
            if tuned is not None:
                schedule = schedule.narrowed(tuned)
        # with a persistent compilation cache, pre-warm the rungs a
        # previous process used for this (sim, B, topology): compiles
        # deserialize from disk in milliseconds instead of stalling the
        # first rounds, and the endgame rung can never compile mid-drain
        if dse_cache.active():
            known = dse_cache.get_rung_set(self.sim, B, d) or []
            cold = [r for r in known if (r, d) not in self._fns]
            if cold:
                self.warm_ladder(template, params_b, cold, shard=d)

        depth = (2 if pipeline is None or pipeline is True else
                 1 if pipeline is False else max(1, int(pipeline)))

        ep = np.broadcast_to(               # per-lane epochs so far
            np.asarray(0 if init_epochs is None else init_epochs,
                       np.int64), (B,)).copy()
        done: list[tuple[list[int], SimState]] = []   # finished segments
        pending = list(range(B))            # configs not yet started
        pool: list[tuple[list[int], SimState]] = []   # alive, unscheduled
        tuner = (ChunkAutotuner(schedule, len(pending))
                 if schedule.autotune else None)
        pad_template = template[0] if per_lane else template
        n_rounds = 0
        n_dispatched = 0
        host_accum = wait_accum = 0.0
        used_rungs: set[int] = set()
        shard_of: dict[int, int] = {}   # config -> mesh slot last round
        if BUS.active:
            BUS.emit("rounds.start", B=B, per_lane=per_lane,
                     ladder=list(schedule.ladder),
                     quantum=schedule.quantum, shard=d,
                     autotune=bool(schedule.autotune), pipeline=depth)

        def fresh(ids):
            if per_lane:
                return stack_state_list([template[i] for i in ids])
            return stack_states(template, len(ids))

        # two in-flight rounds, resolved FIFO; each entry is a dispatched
        # round whose liveness copy is already streaming to host
        inflight: "collections.deque" = collections.deque()

        def dispatch():
            """Assemble one round from the pool + pending queue and
            enqueue its device step and async liveness pull.  Pure host
            and dispatch work — never blocks on the device, so it runs
            concurrently with the previous round's compute."""
            nonlocal tuner, schedule, pending, n_dispatched
            h0 = time.perf_counter()
            n_alive = sum(len(ids) for ids, _ in pool)
            remaining = n_alive + len(pending)
            rung = None
            if tuner is not None:
                rung = tuner.next_probe(remaining)
                if rung is None:              # probing done: pick winner
                    top = tuner.best(schedule.top)
                    if BUS.active:
                        BUS.emit("autotune.winner", top=top,
                                 rates={str(r): rate for r, rate
                                        in tuner.rates.items()})
                    schedule = schedule.narrowed(top)
                    self._tuned_top[d] = top
                    dse_cache.put_tuned_top(self.sim, d, top)
                    tuner = None
            C = rung if rung is not None else schedule.size_for(remaining)
            # Endgame: once everything left fits the smallest rung there
            # is nothing to compact *into* and no queue to refill from —
            # quantum rounds would be pure overhead, so run to the full
            # budget in one round (this is also the whole story for
            # B <= the smallest rung: one round, monolithic-equivalent).
            # Needs *every* lane resolved, so only when nothing is in
            # flight (in-flight survivors may still need this rung).
            endgame = (tuner is None and not inflight
                       and remaining <= schedule.ladder[-1])

            # --- assemble the round's batch: survivors, refill, pad ----
            parts, ids = [], []
            room = C
            while pool and room:
                seg_ids, seg = pool[0]
                if len(seg_ids) <= room:
                    pool.pop(0)
                    parts.append(seg)
                    ids += seg_ids
                    room -= len(seg_ids)
                else:                 # split a segment across rounds
                    parts.append(jax.tree.map(lambda x: x[:room], seg))
                    pool[0] = (seg_ids[room:],
                               jax.tree.map(lambda x: x[room:], seg))
                    ids += seg_ids[:room]
                    room = 0
            n_fresh = min(room, len(pending))
            spawned: list[int] = []
            if n_fresh:
                take, pending = pending[:n_fresh], pending[n_fresh:]
                parts.append(fresh(take))
                ids += take
                spawned = take
                room -= n_fresh
            if room:                  # zero-horizon padding: freezes on
                parts.append(stack_states(pad_template, room))  # entry
                ids += [-1] * room
            sb = (parts[0] if len(parts) == 1 else
                  jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts))

            rows = np.asarray(ids, np.int32)
            live_row = rows >= 0
            ridx = np.where(live_row, rows, 0)
            if C == B and np.array_equal(ridx, np.arange(B)):
                pb = params_b         # identity round: skip the gather
            else:
                pb = jax.tree.map(lambda x: x[jnp.asarray(ridx)], params_b)
            u_vec = np.where(live_row, u[ridx], 0.0).astype(np.float32)
            cap = budget[ridx].astype(np.int64) if endgame else \
                np.minimum(ep[ridx] + schedule.quantum,
                           budget[ridx].astype(np.int64))
            m_vec = np.where(live_row, cap, 0).astype(np.int32)
            b_vec = np.where(live_row, budget[ridx], 0).astype(np.int32)

            used_rungs.add(C)
            tele = BUS.active         # snapshot once per round
            if tele and d > 1:
                # global re-pack diagnostics: which mesh slot does each
                # live config land on this round, vs where it ran last
                # round — moved lanes are exactly the cross-shard
                # rebalancing the pmap path couldn't do
                per_dev = C // d
                moved = n_live = 0
                for j, i in enumerate(ids):
                    if i < 0:
                        continue
                    n_live += 1
                    slot = j // per_dev
                    if i in shard_of and shard_of[i] != slot:
                        moved += 1
                    shard_of[i] = slot
                BUS.emit("shard.rebalance", round=n_dispatched, shards=d,
                         moved=moved, lanes=n_live)
                BUS.count("dse.shard.lanes_moved", moved)
            t0 = time.perf_counter()
            out = self.run_batch(sb, pb, u_vec, m_vec, d)
            pend = self._liveness_start(out, u_vec, b_vec)
            n_dispatched += 1
            return {"ids": ids, "out": out, "pend": pend, "C": C,
                    "rung": rung, "endgame": endgame,
                    "live_row": live_row, "spawned": spawned,
                    "round": n_dispatched - 1,
                    "t_dispatch": t0, "host_s": t0 - h0}

        def resolve(rec):
            """Block on a dispatched round's liveness (the copy has been
            streaming since dispatch), then harvest finished lanes and
            compact survivors back into the pool."""
            nonlocal n_rounds, host_accum, wait_accum
            (live, ep_c), wait_s = self._liveness_read(rec["pend"])
            dt = time.perf_counter() - rec["t_dispatch"]
            h0 = time.perf_counter()
            ids, out, C = rec["ids"], rec["out"], rec["C"]
            live_row, spawned = rec["live_row"], rec["spawned"]
            tele = BUS.active

            round_epochs = 0
            surv_rows, surv_ids = [], []
            fin_rows, fin_ids = [], []
            for j, i in enumerate(ids):
                if i < 0:
                    continue
                if tele:
                    round_epochs += int(ep_c[j]) - int(ep[i])
                ep[i] = int(ep_c[j])
                if live[j]:
                    surv_rows.append(j)
                    surv_ids.append(i)
                else:
                    fin_rows.append(j)
                    fin_ids.append(i)
            # compaction / harvest: one gather per leaf per group (lane
            # slicing per config would be ~leaves x lanes dispatches);
            # a round the whole batch finishes (or survives) needs none
            if fin_rows:
                if len(fin_rows) == C:
                    done.append((fin_ids, out))
                else:
                    g = jnp.asarray(np.asarray(fin_rows, np.int32))
                    done.append((fin_ids,
                                 jax.tree.map(lambda x: x[g], out)))
            if surv_rows:
                if len(surv_rows) == C:
                    pool.append((surv_ids, out))
                else:
                    g = jnp.asarray(np.asarray(surv_rows, np.int32))
                    pool.append((surv_ids,
                                 jax.tree.map(lambda x: x[g], out)))
            host_s = rec["host_s"] + (time.perf_counter() - h0)
            host_accum += host_s
            wait_accum += wait_s
            if tuner is not None:
                tuner.record(C, dt, lanes=int(np.sum(live_row)),
                             host_dt=host_s)
                if tele and C in tuner.rates:
                    BUS.emit("autotune.probe", rung=C, dur=dt,
                             lanes=int(np.sum(live_row)),
                             rate=tuner.rates[C])
            else:
                q0 = schedule.quantum
                schedule.grow_quantum(dt, host_s, steps=depth)
                if tele and schedule.quantum != q0:
                    BUS.emit("quantum.grow", quantum=schedule.quantum,
                             was=q0, round_dur=dt, host_s=host_s)
            if tele:
                # the per-round heartbeat: lane spawn/freeze/harvest and
                # the compaction decision, one event per drained round
                overlap = host_s / max(host_s + wait_s, 1e-9)
                BUS.emit(
                    "round.end", round=rec["round"], rung=C, dur=dt,
                    live=int(np.sum(live_row)), fresh=len(spawned),
                    pad=int(np.sum(~live_row)), epochs=round_epochs,
                    finished=len(fin_ids), survivors=len(surv_ids),
                    pending=len(pending),
                    pool=sum(len(g) for g, _ in pool),
                    quantum=schedule.quantum,
                    endgame=bool(rec["endgame"]),
                    probe=rec["rung"] is not None,
                    compacted=bool(surv_rows)
                    and len(surv_rows) != C,
                    inflight=len(inflight),
                    host_s=host_s, wait_s=wait_s,
                    overlap_frac=overlap,
                    spawned_ids=spawned[:128],
                    frozen_ids=fin_ids[:128])
                BUS.count("dse.rounds")
                BUS.count("dse.lanes_finished", len(fin_ids))
                BUS.observe("dse.round_s", dt)
                BUS.gauge("dse.lanes_live", len(surv_ids))
                BUS.gauge("dse.lanes_pending", len(pending))
                BUS.gauge("dse.round.overlap_frac", overlap)
            n_rounds += 1

        while pool or pending or inflight:
            # fill the pipeline: dispatch up to ``depth`` rounds before
            # blocking on the oldest round's liveness — round k+1's
            # assembly/dispatch overlaps round k's device compute.
            # Probe rounds stay unpipelined (they need clean per-round
            # timings) and the endgame is terminal by construction.
            while (pool or pending) and len(inflight) < depth:
                inflight.append(dispatch())
                if inflight[-1]["endgame"] or tuner is not None:
                    break
            resolve(inflight.popleft())

        occ = host_accum / max(host_accum + wait_accum, 1e-9)
        self.last_rounds = {"rounds": n_rounds, "chunk": schedule.top,
                            "quantum": schedule.quantum, "shard": d,
                            "pipeline": depth,
                            "host_s": host_accum, "wait_s": wait_accum,
                            "overlap_frac": occ,
                            "trace_count": self.trace_count}
        # remember which rungs this (sim, B, topology) actually compiled
        # so the next process can pre-warm them from the persistent cache
        dse_cache.put_rung_set(self.sim, B, d, used_rungs)
        if BUS.active:
            BUS.emit("rounds.end", B=B, rounds=n_rounds,
                     chunk=schedule.top, quantum=schedule.quantum,
                     shard=d, pipeline=depth, overlap_frac=occ,
                     trace_count=self.trace_count)
        # final assembly in point order: concat the finished segments
        # once, then one gather per leaf restores lane order
        all_ids = np.asarray([i for ids, _ in done for i in ids], np.int32)
        full = (done[0][1] if len(done) == 1 else
                jax.tree.map(lambda *xs: jnp.concatenate(xs),
                             *[t for _, t in done]))
        if np.array_equal(all_ids, np.arange(B)):
            return full               # already in point order
        pos = np.empty(B, np.int32)
        pos[all_ids] = np.arange(B, dtype=np.int32)
        g = jnp.asarray(pos)
        return jax.tree.map(lambda x: x[g], full)


# ---------------------------------------------------------------------------
_RUNNERS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def runner_for(sim) -> BatchRunner:
    """The shared :class:`BatchRunner` of a simulation (weak-keyed, so
    dropping the sim drops its runner and executables).

    ``run_sweep`` uses this instead of a private runner per call: when a
    build function memoizes and returns the *same* ``Simulation`` again,
    repeat sweeps reuse its compiled rungs and autotuned chunk instead
    of re-jitting and re-probing (a build function that rebuilds per
    call compiles fresh either way — structure is the compile key).
    """
    r = _RUNNERS.get(sim)
    if r is None:
        r = _RUNNERS[sim] = BatchRunner(sim)
    return r


def memoize_build(build_fn: Callable) -> Callable:
    """Memoize a sweep build function across calls, so incremental point
    submission (search rounds, repeated sweeps) reuses one built
    simulation — and therefore :func:`runner_for`'s compiled rungs and
    autotuned chunk — instead of rebuilding and recompiling per round.

    * Plain groups: the ``(sim, state)`` of each distinct ``static.*``
      kwarg combination is cached and returned as-is (``run_sweep``
      copies the template state per lane, so it is never consumed).
    * Topology families (``shape=`` calls): the cached family is reused
      whenever its ``shape_max`` covers the requested shape — a search
      round asking for a *smaller* maximum (survivors shrank) runs as
      masked lanes of the already-compiled family.  A request that
      exceeds the cache is rebuilt at the elementwise maximum of old and
      new, so repeated growth converges to one family per group.  When a
      campaign cache dir is configured (``repro.dse.cache``) the union
      also persists *across processes*, keyed on the build function +
      static kwargs: a fresh process builds the family at the previous
      process's final maximum in one shot, so its executable shapes
      match the persistent compilation cache exactly instead of
      re-walking the growth sequence.

    The wrapper forwards ``build_fn``'s signature (``functools.wraps``),
    so ``run_sweep``'s eager ``static.*`` kwarg validation still sees
    the real keyword names.  Idempotent to re-wrap; keep the wrapper
    itself alive to keep the cache (and the weak-keyed runners) alive.
    """
    if getattr(build_fn, "_dse_memoized", False):
        return build_fn
    cache: dict[tuple, object] = {}

    @functools.wraps(build_fn)
    def wrapped(*args, **kw):
        shape = kw.pop("shape", None)
        # family and plain builds of the same static kwargs return
        # different objects — keep them in disjoint cache slots
        key = (shape is not None, args, tuple(sorted(kw.items())))
        if shape is None:
            if key not in cache:
                cache[key] = build_fn(*args, **kw)
            return cache[key]
        fam = cache.get(key)
        if fam is not None and all(
                fam.shape_max.get(a, 0) >= int(v)
                for a, v in shape.items()):
            return fam
        grown = dict(shape)
        if fam is not None:
            for a, v in fam.shape_max.items():
                grown[a] = max(int(grown.get(a, 0)), int(v))
        bkey = None
        if dse_cache.active():        # cross-process union (same axes only
            bkey = dse_cache.family_build_key(build_fn, args, kw)
            persisted = dse_cache.get_family_shape(bkey)
            if persisted:             # — a foreign axis would leak into
                for a, v in persisted.items():   # the build signature)
                    if a in grown:
                        grown[a] = max(int(grown[a]), int(v))
        fam = build_fn(*args, **kw, shape=grown)
        cache[key] = fam
        if bkey is not None:
            dse_cache.put_family_shape(bkey, fam.shape_max)
        return fam

    wrapped._dse_memoized = True
    return wrapped


def _static_kwarg_names(build_fn) -> list[str] | None:
    """Keyword names ``build_fn`` accepts, or None if it takes **kwargs
    (then any ``static.*`` axis must be assumed valid)."""
    try:
        sig = inspect.signature(build_fn)
    except (TypeError, ValueError):
        return None
    params = sig.parameters.values()
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return None
    return [p.name for p in params
            if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                          inspect.Parameter.KEYWORD_ONLY)]


def _extract_arity(fn) -> int:
    """2 for the classic ``extract(sim, lane_state)`` signature, 3 when
    the extractor also wants the point's global index (``extract(sim,
    lane_state, index)`` — what :class:`~repro.dse.mux.LaneMux` uses to
    route rows back to their owning job)."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return 2
    n = 0
    for p in sig.parameters.values():
        if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                      inspect.Parameter.POSITIONAL_OR_KEYWORD):
            n += 1
        elif p.kind is inspect.Parameter.VAR_POSITIONAL:
            return 3
    return 3 if n >= 3 else 2


def run_sweep(build_fn: Callable, spec: SweepSpec, until,
              extract: Callable | None = None, chunk: int | None = None,
              max_epochs: "int | Sequence[int]" = 2_000_000,
              shard: "bool | int" = False,
              schedule: ChunkSchedule | None = None,
              resume: Sequence[ResumeHandle | None] | None = None,
              return_states: bool = False,
              pipeline: "bool | int | None" = None):
    """Simulate every design point of ``spec`` and return tidy result rows.

    ``build_fn(**static_kwargs) -> (sim, state)`` builds the topology; it
    is called once per distinct ``static.*`` axis combination (each such
    group compiles once and vmaps its traced points).  ``extract(sim,
    final_lane_state) -> dict`` pulls per-config results (default: engine
    counters); lanes are handed to it *host-side* — one ``jax.device_get``
    per chunk — so scalar casts in the extractor never sync.  An extractor
    that takes a third positional arg gets the point's global spec index
    too (``extract(sim, lane_state, index)`` — how
    :class:`~repro.dse.mux.LaneMux` routes rows of interleaved jobs).
    Rows come back in spec order, each the point's axis assignment merged
    with its extracted results.

    Execution is **round-based and straggler-free**
    (:meth:`BatchRunner.run_rounds`): every group streams through the
    chunk ladder with per-lane horizons, lane compaction and pending-
    queue refill, so arbitrary B runs through a handful of cached
    executables with zero recompiles after warmup.  ``chunk`` pins the
    ladder's top rung (otherwise large groups autotune it); ``schedule``
    overrides the whole policy.  ``until`` may be a scalar or a per-point
    sequence (mixed horizons — e.g. successive-halving search rounds).
    ``shard=True`` (or a device count) spans each round over the lane
    mesh with globally-rebalanced compaction — rows stay bit-identical
    to the single-device path (:meth:`BatchRunner.run_rounds`).
    ``pipeline`` forwards to :meth:`BatchRunner.run_rounds` — rounds
    pipeline at depth 2 by default (host compaction overlaps device
    compute); ``pipeline=False`` restores the alternating loop,
    bit-identically.

    **Topology families** (``shape.*`` axes, DSE.md): shape axes sweep
    instance counts / wiring *without* forming compile groups.  The
    runner groups by ``static.*`` only, computes each group's family
    maximum per shape axis, and calls ``build_fn(**static_kwargs,
    shape={axis: max})``, which must return a
    :class:`~repro.dse.family.TopologyFamily`.  Every shape in the group
    then runs as lanes of the same ladder rungs — activity masks and
    per-lane initial states select each sub-shape, and masked lanes
    compose with per-lane horizons (a masked lane's next-event min
    simply reaches its horizon earlier).

    All axis paths are validated before anything runs: unknown axes
    raise ``ValueError`` naming the path and the valid alternatives.

    **Warm resume** (``resume=``): a per-point sequence of
    :class:`ResumeHandle` / ``None``.  A handled point's lane starts
    from the handle's frozen final state instead of a fresh template
    copy and simply runs on to its (longer, absolute) ``until`` — the
    engine's epoch sequence is state-determined, so the result row is
    bit-identical to a cold run at that horizon while only the cycles
    *since the handle* are newly simulated.  ``return_states=True``
    returns ``(rows, LaneStates)`` — lazy per-point final states (from
    the same host transfer the rows use) that a search can package into
    next-rung handles.
    """
    if chunk is not None and schedule is not None:
        raise ValueError(
            "pass either chunk= (pins the ladder top) or schedule= (the "
            "whole policy), not both — a schedule carries its own ladder")
    if resume is not None and len(resume) != len(spec):
        raise ValueError(
            f"resume= must give one handle (or None) per point: "
            f"{len(resume)} != {len(spec)}")
    dse_cache.ensure_enabled()       # enable-on-first-sweep: wire the
    # persistent jax compilation cache when a campaign dir is configured
    rows: list[dict | None] = [None] * len(spec)
    lane_states = LaneStates() if return_states else None
    until_arr = np.broadcast_to(np.asarray(until, np.float32), (len(spec),))
    me_arr = np.broadcast_to(np.asarray(max_epochs, np.int64), (len(spec),))
    shape_mode = spec.has_shape_axes()
    tele = BUS.active
    sweep_t0 = time.perf_counter()
    if tele:
        BUS.emit("sweep.start", n_points=len(spec), axes=spec.summary(),
                 shape_mode=bool(shape_mode), shard=_shard_devices(shard),
                 warm=(0 if resume is None
                       else sum(1 for h in resume if h is not None)))
        BUS.count("dse.sweeps")
    static_ok = _static_kwarg_names(build_fn)
    if static_ok is not None:
        bad = [a for a in spec.axes if a.startswith(STATIC_PREFIX)
               and a[len(STATIC_PREFIX):] not in static_ok]
        if bad:
            raise ValueError(
                f"invalid static axes {bad}: build function accepts "
                f"only {sorted(static_ok)}")
    group_no = 0
    for static_kwargs, indices, traced in spec.split_static():
        if tele:
            BUS.emit("sweep.group", group=group_no,
                     static={k: str(v) for k, v in static_kwargs.items()},
                     n_points=len(indices), family=bool(shape_mode))
        group_no += 1
        # validate each group's own axes against that group's build (a
        # group's sim can differ structurally, e.g. static.n_cores, so
        # neither the whole-spec union nor a single target would do)
        group_spec = SweepSpec(tuple(traced))
        u_group = until_arr[np.asarray(indices)]
        me_group = me_arr[np.asarray(indices)]
        res = ([resume[i] for i in indices] if resume is not None
               else None)
        warm = res is not None and any(h is not None for h in res)
        init_ep = (np.asarray([int(h.epochs) if h is not None else 0
                               for h in res], np.int64) if warm else None)
        sched = auto_schedule(len(indices), chunk=chunk) \
            if schedule is None and chunk is not None else schedule
        if shape_mode:
            split = [split_shape(pt) for pt in traced]
            fam_shape: dict[str, int] = {}
            for shape_pt, _ in split:
                for name, v in shape_pt.items():
                    fam_shape[name] = max(int(v), fam_shape.get(name, 1))
            fam = build_fn(**static_kwargs, shape=fam_shape)
            if not isinstance(fam, TopologyFamily):
                raise TypeError(
                    "shape.* axes require a family-aware build function: "
                    "build_fn(**static, shape={...}) must return a "
                    f"TopologyFamily, got {type(fam).__name__}")
            group_spec.validate(fam)
            sim = fam.sim
            base = sim.default_params()
            # grids repeat shapes across traced-axis combinations: derive
            # each distinct shape's masks once and share them between the
            # lane's params and initial state
            mask_cache: dict[tuple, tuple] = {}
            plist, states = [], []
            for shape_pt, traced_pt in split:
                full = fam.full_shape(shape_pt)
                key = tuple(sorted(full.items()))
                if key not in mask_cache:
                    mask_cache[key] = fam.masks(full)
                m = mask_cache[key]
                plist.append(fam.params_for(
                    full, apply_point(base, traced_pt), masks=m))
                states.append(fam.state_for(full, masks=m))
            if warm:                # handled lanes continue, not restart
                states = [h.state if h is not None else s
                          for h, s in zip(res, states)]
            params_b = stack_params(plist)
            runner = runner_for(sim)
            out = runner.run_rounds(states, params_b, u_group,
                                    schedule=sched, max_epochs=me_group,
                                    shard=shard, init_epochs=init_ep,
                                    pipeline=pipeline)
        else:
            sim, st = build_fn(**static_kwargs)
            group_spec.validate(sim)
            params_b = build_param_batch(sim, traced)
            runner = runner_for(sim)
            template = ([h.state if h is not None else st for h in res]
                        if warm else st)
            out = runner.run_rounds(template, params_b, u_group,
                                    schedule=sched, max_epochs=me_group,
                                    shard=shard, init_epochs=init_ep,
                                    pipeline=pipeline)
        # one device_get serves both the result rows and (when asked)
        # the resumable final states — never two transfers per group
        ex = extract or default_extract
        t0 = time.perf_counter()
        host = jax.device_get(out)
        if tele:
            dt = time.perf_counter() - t0
            BUS.emit("transfer", what="rows", lanes=len(indices), dur=dt,
                     bytes=int(sum(x.nbytes for x in jax.tree.leaves(host)
                                   if hasattr(x, "nbytes"))))
            BUS.observe("dse.transfer.rows_s", dt)
        if _extract_arity(ex) >= 3:     # index-aware: mux row routing
            group_rows = [ex(sim, lane(host, j), indices[j])
                          for j in range(len(indices))]
        else:
            group_rows = [ex(sim, lane(host, j))
                          for j in range(len(indices))]
        if lane_states is not None:
            lane_states.add_group(host, indices)
        for j, i in enumerate(indices):
            row = dict(spec.points[i])
            row.update(group_rows[j])
            rows[i] = row
    if tele:
        BUS.emit("sweep.end", n_points=len(spec), groups=group_no,
                 dur=time.perf_counter() - sweep_t0)
    if return_states:
        return list(rows), lane_states
    return list(rows)
