"""Batched design-space execution: vmap the engine's fused hot loop over a
stacked :class:`~repro.core.SimParams` batch.

One jitted program simulates every design point of a topology at once:
``jax.vmap`` maps the ``while_loop`` body over the config axis (lanes whose
horizon/workload is exhausted are frozen by the loop's batching rule, so a
B=1 batch is *bit-identical* to the unbatched engine — the invariant pinned
by ``tests/dse``).  Params enter the loop as broadcast operands only, so
the scatter-free hot-loop property (ENGINE_PERF.md) survives batching.

Execution knobs:

* **Chunking** — ``chunk=`` splits B into fixed-size slabs so B >> memory
  (or >> useful vector width) still runs; every slab reuses the same
  compiled program (the last one is padded, padding lanes discarded).
* **Sharding** — ``shard=True`` pmaps the chunk over local devices (the
  config axis is embarrassingly parallel); with one device this is the
  plain vmap path.  Multi-host sharding is future work (ROADMAP).
* **Donation** — batched states are donated into the loop exactly like the
  unbatched engine (build knob ``donate=``); ``stack_states`` materializes
  fresh per-lane copies so no lane aliases another lane or the template
  state (donating an aliased batch would corrupt sibling configs).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import SimParams, SimState, Stats, check_not_consumed

from .family import TopologyFamily
from .sweep import (STATIC_PREFIX, SweepSpec, apply_point,
                    build_param_batch, split_shape, stack_params,
                    stack_trees)


def stack_states(state: SimState, n: int) -> SimState:
    """``n`` independent copies of ``state`` stacked on a new leading axis.

    ``jnp.stack`` materializes one fresh buffer per leaf — lanes never
    alias each other or the input, so the result is safe to donate while
    ``state`` stays reusable as a template.
    """
    return jax.tree.map(lambda x: jnp.stack([x] * n), state)


def stack_state_list(states: Sequence[SimState]) -> SimState:
    """Stack *distinct* per-lane states (e.g. one per family sub-shape)
    into a batch.  Fresh buffers per leaf, like :func:`stack_states`."""
    return stack_trees(states)


def lane(tree, i: int):
    """Extract config ``i``'s slice from a batched pytree (host-side)."""
    return jax.tree.map(lambda x: x[i], tree)


def default_extract(sim, s: SimState) -> dict:
    """Per-config scalar results: virtual time + engine counters."""
    return {
        "virtual_time": float(s.time),
        "epochs": int(s.stats.epochs),
        "ticks": int(s.stats.ticks),
        "progress_ticks": int(s.stats.progress_ticks),
        "delivered": int(s.stats.delivered),
    }


class BatchRunner:
    """Compiled batched runs over one :class:`Simulation`'s design space.

    Jitted executables are cached per (batch size, max_epochs, shard)
    triple, so chunked sweeps and repeated calls never recompile.
    """

    def __init__(self, sim):
        self.sim = sim
        self._fns: dict[tuple, Callable] = {}

    # ------------------------------------------------------------------
    def _batched_fn(self, b: int, max_epochs: int, shard: bool):
        key = (b, max_epochs, shard)
        fn = self._fns.get(key)
        if fn is not None:
            return fn
        sim = self.sim

        def one(s, p, u):
            return sim._run(s, u, max_epochs, params=p)

        vm = jax.vmap(one, in_axes=(0, 0, None))
        if shard and jax.local_device_count() > 1:
            d = jax.local_device_count()
            while b % d:
                d -= 1            # largest divisor of B we can pmap over

            pm = jax.pmap(vm, in_axes=(0, 0, None),
                          donate_argnums=(0,) if sim.donate else ())

            def fn(sb, pb, u, d=d):
                # the per-device reshaped copy is what gets donated here —
                # callers must still treat sb as consumed, but its leaves
                # may not be observably deleted on the pmap path
                fold = lambda x: x.reshape((d, b // d) + x.shape[1:])
                unfold = lambda x: x.reshape((b,) + x.shape[2:])
                out = pm(jax.tree.map(fold, sb), jax.tree.map(fold, pb), u)
                return jax.tree.map(unfold, out)
        else:
            fn = jax.jit(
                vm, donate_argnums=(0,) if sim.donate else ())
        self._fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    def run_batch(self, states_b: SimState, params_b: SimParams,
                  until: float, max_epochs: int = 2_000_000,
                  shard: bool = False) -> SimState:
        """One vmapped jitted run of a pre-stacked batch.

        ``states_b`` is donated when the simulation was built with
        ``donate=True`` — treat it as consumed (see ``stack_states`` /
        ``Simulation.copy_state``); reusing a consumed batch raises
        immediately instead of failing deep inside XLA dispatch.
        """
        if self.sim.donate:
            check_not_consumed(states_b)
        b = int(params_b.conn_latency.shape[0])
        fn = self._batched_fn(b, max_epochs, shard)
        return fn(states_b, params_b, jnp.float32(until))

    # ------------------------------------------------------------------
    def run_chunked(self, template: SimState | Sequence[SimState],
                    params_b: SimParams, until: float,
                    chunk: int | None = None,
                    max_epochs: int = 2_000_000,
                    shard: bool = False) -> SimState:
        """Run a B-point batch in fixed-size chunks of fresh state stacks.

        ``template`` is either one ``SimState`` (every lane starts from a
        fresh copy of it) or a sequence of B per-lane states (topology
        families: each lane's initial state encodes its sub-shape's
        workload).  All chunks share one compiled executable; the final
        partial chunk is padded by repeating its last point and the
        padding lanes are dropped from the result.  Returns the stacked
        final states in point order.
        """
        B = int(params_b.conn_latency.shape[0])
        per_lane = isinstance(template, (list, tuple))
        if per_lane:
            assert len(template) == B, (len(template), B)
        chunk = B if chunk is None else max(1, min(int(chunk), B))
        outs = []
        for lo in range(0, B, chunk):
            hi = min(lo + chunk, B)
            part = jax.tree.map(lambda x: x[lo:hi], params_b)
            if hi - lo < chunk:   # pad: repeat the last point
                pad = chunk - (hi - lo)
                part = jax.tree.map(
                    lambda x: jnp.concatenate(
                        [x] + [x[-1:]] * pad), part)
            if per_lane:
                lanes = list(template[lo:hi])
                lanes += [lanes[-1]] * (chunk - len(lanes))
                sb = stack_state_list(lanes)
            else:
                sb = stack_states(template, chunk)
            out = self.run_batch(sb, part, until, max_epochs, shard)
            if hi - lo < chunk:
                out = jax.tree.map(lambda x: x[:hi - lo], out)
            outs.append(out)
        if len(outs) == 1:
            return outs[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs)


# ---------------------------------------------------------------------------
def _static_kwarg_names(build_fn) -> list[str] | None:
    """Keyword names ``build_fn`` accepts, or None if it takes **kwargs
    (then any ``static.*`` axis must be assumed valid)."""
    try:
        sig = inspect.signature(build_fn)
    except (TypeError, ValueError):
        return None
    params = sig.parameters.values()
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return None
    return [p.name for p in params
            if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                          inspect.Parameter.KEYWORD_ONLY)]


def run_sweep(build_fn: Callable, spec: SweepSpec, until: float,
              extract: Callable | None = None, chunk: int | None = None,
              max_epochs: int = 2_000_000, shard: bool = False) -> list[dict]:
    """Simulate every design point of ``spec`` and return tidy result rows.

    ``build_fn(**static_kwargs) -> (sim, state)`` builds the topology; it
    is called once per distinct ``static.*`` axis combination (each such
    group compiles once and vmaps its traced points).  ``extract(sim,
    final_lane_state) -> dict`` pulls per-config results (default: engine
    counters).  Rows come back in spec order, each the point's axis
    assignment merged with its extracted results.

    **Topology families** (``shape.*`` axes, DSE.md): shape axes sweep
    instance counts / wiring *without* forming compile groups.  The
    runner groups by ``static.*`` only, computes each group's family
    maximum per shape axis, and calls ``build_fn(**static_kwargs,
    shape={axis: max})``, which must return a
    :class:`~repro.dse.family.TopologyFamily`.  Every shape in the group
    then runs as one lane of a single compiled vmapped batch — activity
    masks and per-lane initial states select each sub-shape, so a
    1..8-core grid costs one compile instead of one per shape.

    All axis paths are validated before anything runs: unknown axes
    raise ``ValueError`` naming the path and the valid alternatives.
    """
    extract = extract or default_extract
    rows: list[dict | None] = [None] * len(spec)
    shape_mode = spec.has_shape_axes()
    static_ok = _static_kwarg_names(build_fn)
    if static_ok is not None:
        bad = [a for a in spec.axes if a.startswith(STATIC_PREFIX)
               and a[len(STATIC_PREFIX):] not in static_ok]
        if bad:
            raise ValueError(
                f"invalid static axes {bad}: build function accepts "
                f"only {sorted(static_ok)}")
    for static_kwargs, indices, traced in spec.split_static():
        # validate each group's own axes against that group's build (a
        # group's sim can differ structurally, e.g. static.n_cores, so
        # neither the whole-spec union nor a single target would do)
        group_spec = SweepSpec(tuple(traced))
        if shape_mode:
            split = [split_shape(pt) for pt in traced]
            fam_shape: dict[str, int] = {}
            for shape_pt, _ in split:
                for name, v in shape_pt.items():
                    fam_shape[name] = max(int(v), fam_shape.get(name, 1))
            fam = build_fn(**static_kwargs, shape=fam_shape)
            if not isinstance(fam, TopologyFamily):
                raise TypeError(
                    "shape.* axes require a family-aware build function: "
                    "build_fn(**static, shape={...}) must return a "
                    f"TopologyFamily, got {type(fam).__name__}")
            group_spec.validate(fam)
            sim = fam.sim
            base = sim.default_params()
            # grids repeat shapes across traced-axis combinations: derive
            # each distinct shape's masks once and share them between the
            # lane's params and initial state
            mask_cache: dict[tuple, tuple] = {}
            plist, states = [], []
            for shape_pt, traced_pt in split:
                full = fam.full_shape(shape_pt)
                key = tuple(sorted(full.items()))
                if key not in mask_cache:
                    mask_cache[key] = fam.masks(full)
                m = mask_cache[key]
                plist.append(fam.params_for(
                    full, apply_point(base, traced_pt), masks=m))
                states.append(fam.state_for(full, masks=m))
            params_b = stack_params(plist)
            runner = BatchRunner(sim)
            out = runner.run_chunked(states, params_b, until, chunk=chunk,
                                     max_epochs=max_epochs, shard=shard)
        else:
            sim, st = build_fn(**static_kwargs)
            group_spec.validate(sim)
            params_b = build_param_batch(sim, traced)
            runner = BatchRunner(sim)
            out = runner.run_chunked(st, params_b, until, chunk=chunk,
                                     max_epochs=max_epochs, shard=shard)
        out = jax.block_until_ready(out)
        for j, i in enumerate(indices):
            row = dict(spec.points[i])
            row.update(extract(sim, lane(out, j)))
            rows[i] = row
    return list(rows)
