"""Batched design-space execution: vmap the engine's fused hot loop over a
stacked :class:`~repro.core.SimParams` batch.

One jitted program simulates every design point of a topology at once:
``jax.vmap`` maps the ``while_loop`` body over the config axis (lanes whose
horizon/workload is exhausted are frozen by the loop's batching rule, so a
B=1 batch is *bit-identical* to the unbatched engine — the invariant pinned
by ``tests/dse``).  Params enter the loop as broadcast operands only, so
the scatter-free hot-loop property (ENGINE_PERF.md) survives batching.

Execution knobs:

* **Chunking** — ``chunk=`` splits B into fixed-size slabs so B >> memory
  (or >> useful vector width) still runs; every slab reuses the same
  compiled program (the last one is padded, padding lanes discarded).
* **Sharding** — ``shard=True`` pmaps the chunk over local devices (the
  config axis is embarrassingly parallel); with one device this is the
  plain vmap path.  Multi-host sharding is future work (ROADMAP).
* **Donation** — batched states are donated into the loop exactly like the
  unbatched engine (build knob ``donate=``); ``stack_states`` materializes
  fresh per-lane copies so no lane aliases another lane or the template
  state (donating an aliased batch would corrupt sibling configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import SimParams, SimState, Stats

from .sweep import SweepSpec, build_param_batch


def stack_states(state: SimState, n: int) -> SimState:
    """``n`` independent copies of ``state`` stacked on a new leading axis.

    ``jnp.stack`` materializes one fresh buffer per leaf — lanes never
    alias each other or the input, so the result is safe to donate while
    ``state`` stays reusable as a template.
    """
    return jax.tree.map(lambda x: jnp.stack([x] * n), state)


def lane(tree, i: int):
    """Extract config ``i``'s slice from a batched pytree (host-side)."""
    return jax.tree.map(lambda x: x[i], tree)


def default_extract(sim, s: SimState) -> dict:
    """Per-config scalar results: virtual time + engine counters."""
    return {
        "virtual_time": float(s.time),
        "epochs": int(s.stats.epochs),
        "ticks": int(s.stats.ticks),
        "progress_ticks": int(s.stats.progress_ticks),
        "delivered": int(s.stats.delivered),
    }


class BatchRunner:
    """Compiled batched runs over one :class:`Simulation`'s design space.

    Jitted executables are cached per (batch size, max_epochs, shard)
    triple, so chunked sweeps and repeated calls never recompile.
    """

    def __init__(self, sim):
        self.sim = sim
        self._fns: dict[tuple, Callable] = {}

    # ------------------------------------------------------------------
    def _batched_fn(self, b: int, max_epochs: int, shard: bool):
        key = (b, max_epochs, shard)
        fn = self._fns.get(key)
        if fn is not None:
            return fn
        sim = self.sim

        def one(s, p, u):
            return sim._run(s, u, max_epochs, params=p)

        vm = jax.vmap(one, in_axes=(0, 0, None))
        if shard and jax.local_device_count() > 1:
            d = jax.local_device_count()
            while b % d:
                d -= 1            # largest divisor of B we can pmap over

            pm = jax.pmap(vm, in_axes=(0, 0, None),
                          donate_argnums=(0,) if sim.donate else ())

            def fn(sb, pb, u, d=d):
                # the per-device reshaped copy is what gets donated here —
                # callers must still treat sb as consumed, but its leaves
                # may not be observably deleted on the pmap path
                fold = lambda x: x.reshape((d, b // d) + x.shape[1:])
                unfold = lambda x: x.reshape((b,) + x.shape[2:])
                out = pm(jax.tree.map(fold, sb), jax.tree.map(fold, pb), u)
                return jax.tree.map(unfold, out)
        else:
            fn = jax.jit(
                vm, donate_argnums=(0,) if sim.donate else ())
        self._fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    def run_batch(self, states_b: SimState, params_b: SimParams,
                  until: float, max_epochs: int = 2_000_000,
                  shard: bool = False) -> SimState:
        """One vmapped jitted run of a pre-stacked batch.

        ``states_b`` is donated when the simulation was built with
        ``donate=True`` — treat it as consumed (see ``stack_states`` /
        ``Simulation.copy_state``).
        """
        b = int(params_b.conn_latency.shape[0])
        fn = self._batched_fn(b, max_epochs, shard)
        return fn(states_b, params_b, jnp.float32(until))

    # ------------------------------------------------------------------
    def run_chunked(self, template: SimState, params_b: SimParams,
                    until: float, chunk: int | None = None,
                    max_epochs: int = 2_000_000,
                    shard: bool = False) -> SimState:
        """Run a B-point batch in fixed-size chunks of fresh state stacks.

        All chunks share one compiled executable; the final partial chunk
        is padded by repeating its last point and the padding lanes are
        dropped from the result.  Returns the stacked final states in
        point order.
        """
        B = int(params_b.conn_latency.shape[0])
        chunk = B if chunk is None else max(1, min(int(chunk), B))
        outs = []
        for lo in range(0, B, chunk):
            hi = min(lo + chunk, B)
            part = jax.tree.map(lambda x: x[lo:hi], params_b)
            if hi - lo < chunk:   # pad: repeat the last point
                pad = chunk - (hi - lo)
                part = jax.tree.map(
                    lambda x: jnp.concatenate(
                        [x] + [x[-1:]] * pad), part)
            sb = stack_states(template, chunk)
            out = self.run_batch(sb, part, until, max_epochs, shard)
            if hi - lo < chunk:
                out = jax.tree.map(lambda x: x[:hi - lo], out)
            outs.append(out)
        if len(outs) == 1:
            return outs[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs)


# ---------------------------------------------------------------------------
def run_sweep(build_fn: Callable, spec: SweepSpec, until: float,
              extract: Callable | None = None, chunk: int | None = None,
              max_epochs: int = 2_000_000, shard: bool = False) -> list[dict]:
    """Simulate every design point of ``spec`` and return tidy result rows.

    ``build_fn(**static_kwargs) -> (sim, state)`` builds the topology; it
    is called once per distinct ``static.*`` axis combination (each such
    group compiles once and vmaps its traced points).  ``extract(sim,
    final_lane_state) -> dict`` pulls per-config results (default: engine
    counters).  Rows come back in spec order, each the point's axis
    assignment merged with its extracted results.
    """
    extract = extract or default_extract
    rows: list[dict | None] = [None] * len(spec)
    for static_kwargs, indices, traced in spec.split_static():
        sim, st = build_fn(**static_kwargs)
        params_b = build_param_batch(sim, traced)
        runner = BatchRunner(sim)
        out = runner.run_chunked(st, params_b, until, chunk=chunk,
                                 max_epochs=max_epochs, shard=shard)
        out = jax.block_until_ready(out)
        for j, i in enumerate(indices):
            row = dict(spec.points[i])
            row.update(extract(sim, lane(out, j)))
            rows[i] = row
    return list(rows)
