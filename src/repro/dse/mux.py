"""Cross-job lane multiplexing: pack lanes from concurrent sweep jobs
into shared round batches (DSE.md "Multiplexing jobs into shared
batches").

The round loop never cared which campaign a lane belongs to — harvest
and compaction work on opaque lane ids — so two half-full jobs over the
same topology can share chunk-ladder rungs, executables and rounds
instead of each running an underfilled batch.  :class:`LaneMux` is the
front door for that: ``submit()`` any number of jobs (each its own
:class:`~repro.dse.sweep.SweepSpec`, horizon, epoch budget and
extractor), then one ``run()`` interleaves every job's points
round-robin into a single combined spec and drives one
:func:`~repro.dse.runner.run_sweep` over it.

* **Fair refill** — the combined point order *is* the pending-queue
  order, so a round-robin interleave admits each job's lanes at the
  same rate: job B's points don't wait behind the whole of job A.
* **Shared compile groups** — jobs whose points carry the same
  ``static.*`` assignment (and the same build function) land in the
  same compile group and stack into the same vmapped batches; jobs
  with *different* build functions are kept apart by a reserved
  ``static.mux_build`` axis that a dispatching wrapper consumes (their
  groups still share the process's warm caches, just not executables).
* **Per-job row routing** — the combined sweep runs with an
  index-aware extractor (``extract(sim, lane_state, index)``): each
  lane's global index maps back to its owning job, whose own extractor
  produces the row.  ``run()`` returns ``{job_id: rows}`` with each
  job's rows in *its own* spec order, the routing axis stripped — a
  multiplexed job's rows are exactly its solo-run rows, bit-identically
  (``tests/dse/test_mux.py``).

Per-lane horizons and budgets make the mix safe: each point keeps its
own ``until`` / ``max_epochs`` as traced per-lane operands, so a
short job's lanes freeze and harvest while a long job's lanes keep
riding the same rounds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.obs.bus import BUS

from .runner import default_extract, run_sweep
from .schedule import ChunkSchedule
from .sweep import STATIC_PREFIX, SweepSpec

MUX_AXIS = STATIC_PREFIX + "mux_build"   # reserved routing axis


@dataclasses.dataclass
class MuxJob:
    """One submitted sweep job: a spec plus its run knobs.

    ``until`` / ``max_epochs`` may be scalars or per-point sequences
    (they become per-lane operands either way).  ``extract`` follows the
    :func:`~repro.dse.runner.run_sweep` contract.
    """

    job_id: str
    build_fn: Callable
    spec: SweepSpec
    until: object
    extract: Callable | None = None
    max_epochs: object = 2_000_000

    def __post_init__(self):
        for pt in self.spec.points:
            if MUX_AXIS in pt:
                raise ValueError(
                    f"{MUX_AXIS!r} is reserved for job routing; "
                    f"job {self.job_id!r} may not assign it")


class LaneMux:
    """Multiplex several sweep jobs through one shared round loop.

    >>> mux = LaneMux()
    >>> mux.submit("a", build, spec_a, until=800.0)
    >>> mux.submit("b", build, spec_b, until=[...per-point...])
    >>> rows = mux.run()          # {"a": [...], "b": [...]}

    Run knobs (``chunk`` / ``schedule`` / ``shard`` / ``pipeline``)
    apply to the shared loop, passed at :meth:`run`.  A ``LaneMux`` is
    one-shot per ``run()`` but reusable: jobs accumulate until ``run()``
    consumes them.
    """

    def __init__(self):
        self._jobs: list[MuxJob] = []

    def submit(self, job_id: str, build_fn: Callable, spec: SweepSpec,
               until, extract: Callable | None = None,
               max_epochs=2_000_000) -> MuxJob:
        """Queue a job for the next :meth:`run`.  ``job_id`` must be
        unique among queued jobs."""
        if any(j.job_id == job_id for j in self._jobs):
            raise ValueError(f"duplicate job_id {job_id!r}")
        job = MuxJob(job_id=job_id, build_fn=build_fn, spec=spec,
                     until=until, extract=extract, max_epochs=max_epochs)
        self._jobs.append(job)
        return job

    # ------------------------------------------------------------------
    @staticmethod
    def _interleave(jobs: Sequence[MuxJob]):
        """Round-robin combined point order: (job index, local index)
        pairs — position k of every job before position k+1 of any."""
        order: list[tuple[int, int]] = []
        longest = max(len(j.spec) for j in jobs)
        for k in range(longest):
            for ji, job in enumerate(jobs):
                if k < len(job.spec):
                    order.append((ji, k))
        return order

    def run(self, chunk: int | None = None,
            schedule: ChunkSchedule | None = None,
            shard: "bool | int" = False,
            pipeline: "bool | int | None" = None) -> dict[str, list[dict]]:
        """Run every queued job through one shared round loop and return
        ``{job_id: rows}`` (each job's rows in its own spec order)."""
        jobs, self._jobs = self._jobs, []
        if not jobs:
            return {}

        # distinct build functions get a routing axis + dispatch wrapper;
        # a single shared build runs exactly as a plain sweep would
        builds: list[Callable] = []
        build_of: list[int] = []
        for job in jobs:
            try:
                bi = builds.index(job.build_fn)
            except ValueError:
                bi = len(builds)
                builds.append(job.build_fn)
            build_of.append(bi)
        multi_build = len(builds) > 1

        order = self._interleave(jobs)
        points: list[dict] = []
        owner: list[tuple[int, int]] = []     # global index -> (job, local)
        u_all: list[float] = []
        me_all: list[int] = []
        for ji, k in order:
            job = jobs[ji]
            pt = dict(job.spec.points[k])
            if multi_build:
                pt[MUX_AXIS] = build_of[ji]
            points.append(pt)
            owner.append((ji, k))
            u = np.broadcast_to(np.asarray(job.until, np.float32),
                                (len(job.spec),))
            me = np.broadcast_to(np.asarray(job.max_epochs, np.int64),
                                 (len(job.spec),))
            u_all.append(float(u[k]))
            me_all.append(int(me[k]))

        combined = SweepSpec.explicit(points, ragged=True)

        if multi_build:
            def build_fn(mux_build, **kw):
                return builds[int(mux_build)](**kw)
        else:
            build_fn = builds[0]

        extractors = [j.extract or default_extract for j in jobs]

        def route(sim, lane_state, index):
            ji, _ = owner[index]
            return extractors[ji](sim, lane_state)

        if BUS.active:
            BUS.emit("mux.start", jobs=[j.job_id for j in jobs],
                     n_points=len(points), shared_build=not multi_build)
            BUS.count("dse.mux.runs")
        t0 = time.perf_counter()
        rows = run_sweep(build_fn, combined, u_all, extract=route,
                         chunk=chunk, schedule=schedule,
                         max_epochs=me_all, shard=shard,
                         pipeline=pipeline)

        out: dict[str, list[dict]] = {
            j.job_id: [None] * len(j.spec) for j in jobs}
        for g, row in enumerate(rows):
            ji, k = owner[g]
            row.pop(MUX_AXIS, None)           # strip the routing axis
            out[jobs[ji].job_id][k] = row
        if BUS.active:
            BUS.emit("mux.end", jobs=[j.job_id for j in jobs],
                     n_points=len(points),
                     dur=time.perf_counter() - t0)
        return out
