"""repro.dse — batched design-space exploration over the Akita engine.

Architectural research is mostly parameter sweeps.  The engine splits a
simulation's *structure* (build-time constant) from its traced
:class:`~repro.core.SimParams` (connection latencies, tick periods, opt-in
per-kind model params — see DSE.md); this package turns that split into a
sweep subsystem:

  * :mod:`~repro.dse.sweep`  — ``SweepSpec`` (grid / random / explicit
    design points, traced + ``static.*`` axes) and param-batch stacking;
  * :mod:`~repro.dse.runner` — ``BatchRunner`` / ``run_sweep``: one jitted
    ``vmap`` of the fused hot loop simulates hundreds of configs at once
    (chunked for B >> memory, optionally pmapped over devices);
  * :mod:`~repro.dse.report` — tidy rows, Pareto-front extraction and
    JSON/CSV export.

A singleton batch is bit-identical to the unbatched engine — the
invariant that makes sweep results trustworthy (tests/dse).
"""
from .report import format_table, pareto_front, tidy, to_csv, to_json
from .runner import (BatchRunner, default_extract, lane, run_sweep,
                     stack_states)
from .sweep import SweepSpec, apply_point, build_param_batch, stack_params

__all__ = [
    "SweepSpec", "apply_point", "build_param_batch", "stack_params",
    "BatchRunner", "run_sweep", "stack_states", "lane", "default_extract",
    "pareto_front", "tidy", "to_csv", "to_json", "format_table",
]
