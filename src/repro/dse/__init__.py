"""repro.dse — batched design-space exploration over the Akita engine.

Architectural research is mostly parameter sweeps.  The engine splits a
simulation's *structure* (build-time constant) from its traced
:class:`~repro.core.SimParams` (connection latencies, tick periods, opt-in
per-kind model params — see DSE.md); this package turns that split into a
sweep subsystem:

  * :mod:`~repro.dse.sweep`  — ``SweepSpec`` (grid / random / explicit
    design points; traced, ``static.*`` and ``shape.*`` axes; eager path
    validation) and param-batch stacking;
  * :mod:`~repro.dse.family` — ``TopologyFamily``: one padded
    maximum-shape build whose sub-shapes are selected by traced activity
    masks, so instance counts / wiring sweep without recompiling;
  * :mod:`~repro.dse.runner` — ``BatchRunner`` / ``run_sweep``: one jitted
    ``vmap`` of the fused hot loop simulates hundreds of configs at once
    with *per-lane horizons* (``until`` / ``max_epochs`` are traced
    per-lane operands); ``run_rounds`` streams arbitrary B straggler-free
    through rounds + lane compaction (optionally ``shard_map``-sharded
    over a device mesh with globally-rebalanced compaction); shape axes
    lower to mask batches grouped per family, not compile groups;
  * :mod:`~repro.dse.mux` — ``LaneMux``: multiplex lanes from several
    concurrent sweep jobs into shared round batches with fair
    round-robin refill and per-job row routing — half-full campaigns
    share rungs and executables instead of underfilling their own;
  * :mod:`~repro.dse.cache` — the campaign cache: the jax persistent
    compilation cache (enabled on first sweep when a cache dir is
    configured) plus a cross-process artifact store for the autotuned
    rung, warm-ladder rung sets and family shape unions, so the second
    process of a campaign compiles nothing;
  * :mod:`~repro.dse.schedule` — the chunk ladder, epoch-quantum policy
    and the one-shot chunk-size autotuner behind ``run_rounds``;
  * :mod:`~repro.dse.report` — tidy rows, ``dominates`` /
    Pareto-front extraction and JSON/CSV export;
  * :mod:`~repro.dse.search` — closed-loop search drivers
    (``SuccessiveHalving``, ``BatchBO``, ``RandomSearch``) that pick
    points + horizons between rounds under a simulated-cycle budget,
    with resumable JSON-serializable ``SearchState``.

A singleton batch is bit-identical to the unbatched engine, and a
masked family lane is bit-identical on active rows to an unpadded build
of its shape — the invariants that make sweep results trustworthy
(tests/dse).
"""
from . import cache
from .cache import configure as configure_cache
from .family import TopologyFamily
from .mux import LaneMux, MuxJob
from .report import (dominates, format_table, pareto_front, score_vector,
                     tidy, to_csv, to_json)
from .runner import (BatchRunner, LaneStates, ResumeHandle,
                     default_extract, extract_rows, lane,
                     memoize_build, run_sweep, runner_for,
                     stack_state_list, stack_states)
from .schedule import ChunkAutotuner, ChunkSchedule, auto_schedule, \
    make_ladder
from .search import (BatchBO, Objective, RandomSearch, SearchDriver,
                     SearchResult, SearchState, SuccessiveHalving,
                     horizon_ladder, load_search, run_search, save_search)
from .sweep import (SweepSpec, apply_point, axis_error, build_param_batch,
                    split_shape, stack_params, valid_axes)

__all__ = [
    "cache", "configure_cache",
    "SweepSpec", "apply_point", "axis_error", "valid_axes",
    "build_param_batch", "stack_params", "split_shape", "TopologyFamily",
    "BatchRunner", "run_sweep", "stack_states", "stack_state_list", "lane",
    "default_extract", "extract_rows", "runner_for", "memoize_build",
    "ResumeHandle", "LaneStates", "LaneMux", "MuxJob",
    "ChunkSchedule", "ChunkAutotuner", "auto_schedule", "make_ladder",
    "SearchDriver", "SearchState", "SearchResult", "Objective",
    "run_search", "SuccessiveHalving", "horizon_ladder", "BatchBO",
    "RandomSearch", "save_search", "load_search",
    "pareto_front", "dominates", "score_vector", "tidy", "to_csv",
    "to_json", "format_table",
]
