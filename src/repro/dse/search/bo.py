"""Batched Bayesian optimization (dependency-free) and random search.

:class:`BatchBO` fits a Gaussian-process surrogate — an RBF kernel over
axis values normalized to the unit cube, plain numpy Cholesky algebra,
no external optimizer — to the scalarized objective of every trial so
far, then proposes the next *batch* of design points by batched
**Thompson sampling** (one joint posterior draw per batch slot, each
slot takes the draw's argmin — draws differ, so the batch spreads
between exploitation and exploration automatically) or batched **UCB**
(lowest ``mean − beta·std``, best-q distinct) over a fresh candidate
pool drawn with :meth:`SweepSpec.random`.  Everything runs host-side
between rounds on tiny matrices (history × pool); the simulated rounds
themselves go through the same vmapped ``run_sweep`` path as any sweep.

Axis encodings (the surrogate's coordinates, shared with sampling via
:func:`~repro.dse.sweep.parse_axis_spec`): ``(lo, hi)`` ranges — float
or inclusive int — map affinely to [0, 1]; ``(lo, hi, 'log')`` ranges
map in log space; choice lists (including ``shape.*`` family axes) map
by ordinal index — neighbouring choices are assumed more alike than
distant ones, the right prior for monotone axes like sizes and counts.

:class:`RandomSearch` is the baseline every search paper demands: the
same loop, a fresh random batch each round, no model.

Both drivers draw per-round sampling seeds from the driver RNG stream
(persisted in :class:`SearchState`), so seeded runs are
bit-reproducible and mid-search resume continues identically.
"""
from __future__ import annotations

import math
import time
from typing import Mapping

import numpy as np

from repro.obs.bus import BUS

from ..sweep import SweepSpec, parse_axis_spec
from .driver import SearchDriver, SearchState


class RandomSearch(SearchDriver):
    """A fresh random batch at a fixed horizon, every round."""

    def __init__(self, axes: dict, objective, *, horizon: float,
                 batch: int = 16, rounds: int = 8, seed: int = 0,
                 cycle_budget: float | None = None,
                 state: SearchState | None = None):
        super().__init__(objective, seed=seed, cycle_budget=cycle_budget,
                         state=state)
        self.axes = dict(axes)
        self.horizon = float(horizon)
        self.batch = int(batch)
        self.rounds = int(rounds)

    @property
    def max_horizon(self) -> float:
        return self.horizon

    def _done(self) -> bool:
        return self.state.round >= self.rounds

    def _ask(self):
        pts = list(SweepSpec.random(self.axes, self.batch,
                                    seed=self._draw_seed()))
        return pts, [self.horizon] * len(pts)


# ---------------------------------------------------------------------------
def _axis_codec(axes: dict):
    """Per-axis encoders onto [0, 1] (the surrogate's unit cube).

    Spec styles come from :func:`~repro.dse.sweep.parse_axis_spec` —
    the same classifier ``SweepSpec.random`` samples with, so encoding
    can never drift from sampling: float and (inclusive-)int ranges map
    affinely, ``'log'`` ranges map in log space, choice lists by
    ordinal index.
    """
    codecs = {}
    for name, spec in axes.items():
        kind, *args = parse_axis_spec(spec)
        if kind == "log":
            lo, hi = math.log(args[0]), math.log(args[1])
            codecs[name] = (lambda v, lo=lo, hi=hi:
                            (math.log(float(v)) - lo) / max(hi - lo, 1e-12))
        elif kind in ("int", "float"):
            lo, hi = float(args[0]), float(args[1])
            codecs[name] = (lambda v, lo=lo, hi=hi:
                            (float(v) - lo) / max(hi - lo, 1e-12))
        else:
            values = args[0]
            index = {c: i for i, c in enumerate(values)}
            k = max(len(values) - 1, 1)
            codecs[name] = (lambda v, index=index, k=k:
                            index[v] / k if v in index else 0.5)
    return codecs


def _rbf(a: np.ndarray, b: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / (ls * ls))


_erf = np.vectorize(math.erf)


def _ncdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(z / math.sqrt(2.0)))


def _npdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


class BatchBO(SearchDriver):
    """Batched Bayesian optimization over continuous/choice axes.

    ``axes`` uses :meth:`SweepSpec.random` specs.  Round 0 evaluates
    ``batch`` random points (the surrogate needs data); each later round
    refits the GP on all history and proposes ``batch`` points from a
    ``pool``-sized fresh candidate set by ``acquisition`` — ``"ts"``
    (batched Thompson sampling, the default), ``"ucb"``
    (``mean − beta·std``) or ``"qei"`` (greedy constant-liar expected
    improvement: pick the max-EI candidate, append it to the training
    set with a *liar* observation at the incumbent best, refit, repeat
    — each pick's posterior collapses around the previous picks, so
    near-duplicates lose their EI and the batch spreads; the classic
    sequential-simulation qEI approximation).  Exact duplicates of
    evaluated points are excluded from the pool.  ``lengthscale`` is the RBF lengthscale on
    the unit cube; ``noise`` the observation-noise variance (objectives
    here are deterministic simulations — the default is just jitter).
    Multi-objective specs are scalarized (:class:`Objective` weights).
    """

    def __init__(self, axes: dict, objective, *, horizon: float,
                 batch: int = 8, rounds: int = 8, pool: int = 256,
                 acquisition: str = "ts", beta: float = 2.0,
                 lengthscale: float = 0.25, noise: float = 1e-6,
                 seed: int = 0, cycle_budget: float | None = None,
                 state: SearchState | None = None):
        super().__init__(objective, seed=seed, cycle_budget=cycle_budget,
                         state=state)
        assert acquisition in ("ts", "ucb", "qei"), acquisition
        self.axes = dict(axes)
        self.horizon = float(horizon)
        self.batch = int(batch)
        self.rounds = int(rounds)
        self.pool = int(pool)
        self.acquisition = acquisition
        self.beta = float(beta)
        self.lengthscale = float(lengthscale)
        self.noise = float(noise)
        self._codec = _axis_codec(self.axes)

    @property
    def max_horizon(self) -> float:
        return self.horizon

    def _done(self) -> bool:
        return self.state.round >= self.rounds

    # ------------------------------------------------------------------
    def _encode(self, pts) -> np.ndarray:
        return np.asarray([[self._codec[a](p[a]) for a in self.axes]
                           for p in pts], np.float64)

    def _key(self, p: Mapping) -> tuple:
        return tuple(p[a] for a in self.axes)

    def _ask(self):
        if not self.state.history:
            # warmup: the first `batch` *distinct* points of a random
            # pool (choice axes repeat combinations; rerunning an
            # identical deterministic config would be pure waste)
            pts, seen = [], set()
            for p in SweepSpec.random(self.axes,
                                      max(self.pool, self.batch),
                                      seed=self._draw_seed()):
                k = self._key(p)
                if k not in seen:
                    seen.add(k)
                    pts.append(p)
                if len(pts) == self.batch:
                    break
            return pts, [self.horizon] * len(pts)

        hist = self.state.history
        # the pool is deduped against history AND within itself: choice
        # axes repeat combinations, and duplicate candidates would tie
        # on every acquisition value — the batch must be distinct
        # *points*, not distinct pool indices
        seen = {self._key(t) for t in hist}
        cand = []
        for p in SweepSpec.random(self.axes, self.pool,
                                  seed=self._draw_seed()):
            k = self._key(p)
            if k not in seen:
                seen.add(k)
                cand.append(p)
        if not cand:
            return None
        x = self._encode(hist)
        y = np.asarray([self.objective.scalar(t) for t in hist], np.float64)
        finite = np.isfinite(y)
        if finite.any():
            worst = y[finite].max()
            y = np.where(finite, y, worst)       # failed trials = worst
        else:
            y = np.zeros_like(y)
        mu0, sd0 = float(y.mean()), float(y.std()) or 1.0
        yn = (y - mu0) / sd0
        p = self._encode(cand)

        q = min(self.batch, len(cand))
        t0 = time.perf_counter()
        if self.acquisition == "qei":
            picks = self._qei(x, yn, p, q)
        else:
            mean, cov = self._posterior(x, yn, p)
            if self.acquisition == "ucb":
                std = np.sqrt(np.clip(np.diag(cov), 1e-12, None))
                picks = list(np.argsort(mean - self.beta * std,
                                        kind="stable")[:q])
            else:
                picks = self._thompson(mean, cov, q)
        if BUS.active:
            BUS.emit("bo.propose", round=self.state.round,
                     acquisition=self.acquisition, history=len(hist),
                     pool=len(cand), batch=q,
                     dur=time.perf_counter() - t0)
        return [dict(cand[i]) for i in picks], [self.horizon] * q

    def _posterior(self, x, yn, p):
        """GP posterior (mean, covariance) at pool ``p`` given unit-cube
        history ``x`` with standardized objectives ``yn``."""
        n = len(x)
        k = _rbf(x, x, self.lengthscale)
        jitter = max(self.noise, 1e-9)
        for _ in range(6):                      # escalate until PD
            try:
                low = np.linalg.cholesky(k + jitter * np.eye(n))
                break
            except np.linalg.LinAlgError:
                jitter *= 10.0
        else:
            raise np.linalg.LinAlgError("GP kernel not PD")
        ks = _rbf(x, p, self.lengthscale)
        alpha = np.linalg.solve(low.T, np.linalg.solve(low, yn))
        v = np.linalg.solve(low, ks)
        mean = ks.T @ alpha
        cov = _rbf(p, p, self.lengthscale) - v.T @ v
        return mean, cov

    def _qei(self, x, yn, p, q: int) -> list[int]:
        """Greedy constant-liar qEI over pool ``p``: after each pick the
        picked location enters the training set with the incumbent-best
        value (the *liar*), so the refitted posterior's uncertainty —
        and therefore EI — collapses around it and the next pick lands
        somewhere informative instead of on a near-duplicate.  q small
        Cholesky refits on (history + <q) points: host-side noise."""
        xs, ys = [np.asarray(r) for r in x], list(np.asarray(yn))
        best = float(np.min(yn))
        picks: list[int] = []
        for _ in range(q):
            mean, cov = self._posterior(np.asarray(xs), np.asarray(ys), p)
            std = np.sqrt(np.clip(np.diag(cov), 1e-12, None))
            z = (best - mean) / std
            ei = (best - mean) * _ncdf(z) + std * _npdf(z)
            if picks:
                ei[np.asarray(picks, int)] = -np.inf
            picks.append(int(np.argmax(ei)))     # ties -> lowest index
            xs.append(p[picks[-1]])
            ys.append(best)                      # the constant liar
        return picks

    def _thompson(self, mean, cov, q: int) -> list[int]:
        """One joint posterior draw per batch slot; each slot takes its
        draw's argmin (first unpicked position in that draw's order)."""
        m = len(mean)
        jitter = 1e-9
        for _ in range(6):
            try:
                low = np.linalg.cholesky(cov + jitter * np.eye(m))
                break
            except np.linalg.LinAlgError:
                jitter *= 10.0
        else:
            low = np.diag(np.sqrt(np.clip(np.diag(cov), 1e-12, None)))
        picks: list[int] = []
        for _ in range(q):
            draw = mean + low @ self._rng.standard_normal(m)
            for i in np.argsort(draw, kind="stable"):
                if int(i) not in picks:
                    picks.append(int(i))
                    break
        return picks
