"""repro.dse.search — closed-loop design-space search over batched sweeps.

Exhaustive grids are the naive DSE workflow; this package closes the
loop: a :class:`SearchDriver` picks the next design points *and their
horizons* between rounds (``ask()`` → ``tell(rows)``), and every round
executes through :func:`~repro.dse.runner.run_sweep`'s round-based
streaming path — vmapped lanes, per-lane horizons, the chunk ladder,
zero recompiles after warmup (builds are memoized across rounds via
:func:`~repro.dse.runner.memoize_build`).  Budget is accounted in
*simulated cycles*; :class:`SearchState` makes a search resumable and
JSON-serializable mid-flight.

Drivers:

* :class:`SuccessiveHalving` — ASHA-style: run wide at short horizons,
  promote the top ``1/eta`` to geometrically longer ones (the horizon
  ladder); optional Hyperband-style brackets mix horizons in one round.
* :class:`BatchBO` — dependency-free batched Bayesian optimization
  (numpy RBF surrogate, batched Thompson sampling or UCB over a
  :meth:`SweepSpec.random` candidate pool) for continuous axes.
* :class:`RandomSearch` — the no-model baseline.

See DSE.md "Search" and ``examples/search_memsys.py``.
"""
from .bo import BatchBO, RandomSearch
from .driver import (Objective, SearchDriver, SearchResult, SearchState,
                     run_search)
from .halving import SuccessiveHalving, horizon_ladder
from .warm import load_search, save_search

__all__ = [
    "Objective", "SearchDriver", "SearchResult", "SearchState",
    "run_search", "SuccessiveHalving", "horizon_ladder", "BatchBO",
    "RandomSearch", "save_search", "load_search",
]
