"""Rung checkpoints: persist a warm search — trajectory *and* rung-end
states — through :mod:`repro.ckpt`.

:class:`~repro.dse.search.driver.SearchState` alone is JSON and resumes
the *decisions* of a search exactly, but a warm
:class:`~repro.dse.search.halving.SuccessiveHalving` also carries live
:class:`~repro.dse.runner.ResumeHandle`\\ s — the frozen ``SimState`` of
every promoted config.  Dropping them on resume is correct but wasteful:
the first post-resume round replays its rungs from cycle 0.  This module
writes both through the fault-tolerant checkpoint layer (atomic npz +
manifest, exact dtype round-trip — bool masks, integer clocks and
weakly-typed scalars come back bit-identical, ``tests/ckpt``):

* :func:`save_search` — one checkpoint step per search round: each
  handle's state leaves in the npz shard, handle metadata (frozen time /
  horizon / epochs) and the ``SearchState`` JSON in the manifest.
* :func:`load_search` — the reverse: ``(SearchState, handles)``;
  rebuild the driver with ``state=`` and hand it the handles via
  :meth:`~repro.dse.search.halving.SuccessiveHalving.adopt_handles`.

A search resumed this way is **bit-identical** to the uninterrupted one
— same rows, same promotions, same cumulative budget — because the
handles make the post-resume rounds charge the same increments
(tests/dse/test_warm_resume.py).
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.ckpt import list_steps, save_checkpoint
from repro.obs.bus import BUS

from ..runner import ResumeHandle
from .driver import SearchState


def save_search(path: str, driver, step: int | None = None) -> str:
    """Checkpoint ``driver`` under ``path``: rung-end handle states plus
    the serialized :class:`SearchState`.  ``step`` defaults to the
    driver's round counter (one checkpoint per completed round — a
    valid snapshot point).  Returns the written step directory."""
    store: dict = getattr(driver, "_handle_store", {}) or {}
    tree = {k: list(jax.tree.leaves(h.state)) for k, h in store.items()}
    meta = {k: {"time": float(h.time), "until": float(h.until),
                "epochs": int(h.epochs)} for k, h in store.items()}
    step = int(driver.state.round) if step is None else int(step)
    os.makedirs(path, exist_ok=True)
    t0 = time.perf_counter()
    out = save_checkpoint(path, {"handles": tree}, step,
                          extra={"search_state": driver.state.to_json(),
                                 "handles": meta})
    if BUS.active:
        BUS.emit("ckpt.save", path=str(out), step=step,
                 handles=len(store), dur=time.perf_counter() - t0)
    return out


def load_search(path: str, template_state,
                step: int | None = None
                ) -> tuple[SearchState, dict[str, ResumeHandle]]:
    """Restore ``(SearchState, handles)`` from :func:`save_search`.

    ``template_state`` is any :class:`~repro.core.SimState` of the
    searched simulation (e.g. the build function's fresh state) — it
    supplies the tree structure and exact leaf dtypes the stored handle
    states are restored into.  Handle keys are unknown before the
    manifest is read, so the restore template is assembled from it.
    """
    from repro.ckpt import restore_checkpoint

    steps = list_steps(path)
    if not steps:
        raise FileNotFoundError(f"no search checkpoints under {path}")
    step = steps[-1] if step is None else step
    t0 = time.perf_counter()
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as fh:
        manifest = json.load(fh)
    meta = manifest["extra"]["handles"]
    leaves_t = jax.tree.leaves(template_state)
    treedef = jax.tree.structure(template_state)
    template = {"handles": {k: list(leaves_t) for k in meta}}
    tree, manifest = restore_checkpoint(path, template, step)
    handles = {}
    for k, m in meta.items():
        st = jax.tree.unflatten(treedef, tree["handles"][k])
        handles[k] = ResumeHandle(state=st, time=float(m["time"]),
                                  until=float(m["until"]),
                                  epochs=int(m["epochs"]))
    state = SearchState.from_json(manifest["extra"]["search_state"])
    if BUS.active:
        BUS.emit("ckpt.load", path=str(d), step=int(step),
                 handles=len(handles), round=state.round,
                 dur=time.perf_counter() - t0)
    return state, handles
