"""The closed-loop search contract: ``ask() -> (points, horizons)`` /
``tell(rows)`` around round-based sweeps.

A *search driver* owns the between-rounds decisions of a design-space
search — which points to simulate next and how far (in simulated cycles)
to run each one — while every round executes through the existing
:func:`~repro.dse.runner.run_sweep` machinery, so the lanes stay
vmapped, chunk-laddered and zero-recompile after warmup, and the engine
hot loop is untouched (all acquisition/selection logic is host-side
bookkeeping over result rows).

* :class:`Objective` — one or many result columns with directions
  (``"virtual_time"`` or ``{"virtual_time": "min", "hit_rate": "max"}``),
  scalarization weights, non-dominated ranking (via
  :func:`~repro.dse.report.dominates`) and running Pareto fronts.
* :class:`SearchState` — the resumable, JSON-serializable record of a
  search: trial history, cumulative *simulated-cycle* budget, RNG state
  and a driver-specific pocket.  Serializing after any ``tell`` and
  reconstructing the driver with ``state=`` resumes the identical
  trajectory (rows are bit-reproducible, selection is stable-sorted).
* :class:`SearchDriver` — the loop contract plus shared bookkeeping
  (budget accounting in simulated cycles: each trial costs the cycles it
  *newly* simulated — ``row["virtual_time"]`` when the extractor reports
  it, else its horizon, minus the frozen time of the trial's
  :class:`~repro.dse.runner.ResumeHandle` when the lane resumed from a
  previous rung's state instead of replaying from cycle 0).
* :func:`run_search` — the driver loop: memoize the build function
  (:func:`~repro.dse.runner.memoize_build`, so every round reuses one
  built simulation and its tuned ladder), then ``ask`` → ``run_sweep``
  → ``tell`` until the driver is done.

Concrete drivers: :class:`~repro.dse.search.halving.SuccessiveHalving`,
:class:`~repro.dse.search.bo.BatchBO` and
:class:`~repro.dse.search.bo.RandomSearch`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.obs.bus import BUS

from ..report import MAX, MIN, pareto_front, score_vector, _dominates_scores
from ..runner import (LaneStates, ResumeHandle, _shard_devices,
                      memoize_build, run_sweep)
from ..schedule import ChunkSchedule
from ..sweep import SweepSpec


class Objective:
    """What the search optimizes: result columns + directions.

    ``spec`` is a column name (minimized) or a ``{column: 'min'|'max'}``
    mapping.  Multi-objective searches either *scalarize* — ``scalar``
    is the weighted sum of the canonical minimize-direction values
    (``weights`` defaults to 1.0 each) — or rank by domination:
    ``order`` sorts rows best-first by (number of rows in the batch that
    dominate it, scalarized value, input index), so non-dominated rows
    are promoted first and the scalarization only breaks ties.  For a
    single objective both reduce to a stable sort on the column.

    NaN or missing objective values scalarize to ``+inf`` (never
    selected over a finished trial) and neither dominate nor are
    dominated, matching :func:`~repro.dse.report.pareto_front`.
    """

    def __init__(self, spec: str | Mapping[str, str],
                 weights: Mapping[str, float] | None = None):
        if isinstance(spec, str):
            spec = {spec: MIN}
        self.objectives = dict(spec)
        assert self.objectives and all(
            d in (MIN, MAX) for d in self.objectives.values()), spec
        self.weights = {c: float((weights or {}).get(c, 1.0))
                        for c in self.objectives}

    @property
    def columns(self) -> list[str]:
        return list(self.objectives)

    def scalar(self, row: Mapping) -> float:
        """Scalarized objective, lower is better; NaN/missing -> +inf."""
        total = 0.0
        for c, d in self.objectives.items():
            try:
                v = float(row[c])
            except (KeyError, TypeError, ValueError):
                return float("inf")
            if v != v:
                return float("inf")
            total += self.weights[c] * (-v if d == MAX else v)
        return total

    def order(self, rows: Sequence[Mapping]) -> list[int]:
        """Indices of ``rows`` sorted best-first (stable)."""
        scalars = [self.scalar(r) for r in rows]
        inf = float("inf")
        if len(self.objectives) == 1:
            key = lambda i: (scalars[i], i)
        else:
            scores = []
            for r in rows:
                try:
                    s = score_vector(r, self.objectives)
                except (KeyError, TypeError, ValueError):
                    s = (float("nan"),) * len(self.objectives)
                scores.append(s)
            dom = [sum(_dominates_scores(o, s) for o in scores)
                   for s in scores]
            # failed trials (scalar == inf: NaN/missing objectives) rank
            # behind every finished row — a NaN score is never dominated,
            # so domination count alone would promote it over finished
            # but dominated rows
            key = lambda i: (scalars[i] == inf, dom[i], scalars[i], i)
        return sorted(range(len(rows)), key=key)

    def front(self, rows: Sequence[Mapping]) -> list[dict]:
        """Non-dominated ``rows`` (the running Pareto front)."""
        return pareto_front(rows, self.objectives)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SearchState:
    """The resumable record of a search — everything a driver needs to
    continue is either here or in the driver's constructor arguments.

    ``history`` holds one flat trial dict per evaluated (point, horizon)
    pair: the sweep result row (axis assignments merged with extracted
    columns) plus ``"until"`` (the horizon it ran to) and ``"round"``.
    ``budget`` is the cumulative *simulated-cycle* spend.  ``rng`` is
    the numpy bit-generator state of the driver's RNG.  ``driver`` is a
    JSON-safe pocket for driver-specific progress (survivor sets, rung
    indices, ...).

    Valid snapshot points are round boundaries (after ``tell``) —
    ``SearchDriver.tell`` refreshes ``rng`` there, and ``run_search``'s
    ``callback`` fires there.  Restoring: rebuild the driver with the
    same constructor arguments plus ``state=``; the remaining trajectory
    is identical (pinned by ``tests/dse/test_search.py``).
    """

    round: int = 0
    budget: float = 0.0
    history: list = dataclasses.field(default_factory=list)
    driver: dict = dataclasses.field(default_factory=dict)
    rng: dict | None = None

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "SearchState":
        return SearchState(**json.loads(s))


# ---------------------------------------------------------------------------
class SearchDriver:
    """Base class: the ``ask``/``tell`` loop contract plus shared
    bookkeeping (history, simulated-cycle budget, RNG persistence).

    Subclasses implement ``_ask() -> (points, horizons) | None`` —
    optionally ``(points, horizons, handles)`` with one
    :class:`~repro.dse.runner.ResumeHandle` (or None) per point, the
    warm-resume contract — and ``_tell(points, horizons, rows,
    states=None)`` (selection/acquisition; ``states`` is the sweep's
    :class:`~repro.dse.runner.LaneStates` when the driver declared
    ``wants_states``), and may override ``done``.  ``seed`` feeds a
    numpy RNG whose state rides :class:`SearchState`, so a resumed
    driver continues the same stream.  ``cycle_budget`` (optional)
    hard-stops the search once the cumulative simulated-cycle spend
    reaches it.
    """

    def __init__(self, objective: str | Mapping[str, str] | Objective,
                 *, seed: int = 0, cycle_budget: float | None = None,
                 state: SearchState | None = None):
        self.objective = (objective if isinstance(objective, Objective)
                          else Objective(objective))
        self.cycle_budget = cycle_budget
        self.state = state if state is not None else SearchState()
        self._rng = np.random.default_rng(seed)
        if self.state.rng is not None:
            self._rng.bit_generator.state = self.state.rng
        self._asked: tuple[list[dict], list[float]] | None = None
        self._handles: list[ResumeHandle | None] | None = None
        self._costs: list[float] | None = None

    # -- the loop contract ------------------------------------------------
    def ask(self) -> tuple[list[dict], list[float]] | None:
        """The next round: ``(points, horizons)`` — parallel lists, one
        horizon (simulated-cycle ``until``) per design point — or
        ``None`` when the search is finished.  When the driver resumes
        lanes from previous-rung states, the per-point handles are on
        :attr:`resume_handles` (``run_search`` feeds them to
        ``run_sweep(resume=...)``)."""
        if self.done:
            return None
        asked = self._ask()
        self._handles = None
        if asked is not None:
            if len(asked) == 3:
                points, horizons, handles = asked
                if handles is not None and any(h is not None
                                               for h in handles):
                    assert len(handles) == len(points), asked
                    self._handles = list(handles)
            else:
                points, horizons = asked
            assert len(points) == len(horizons), asked
            if not points:
                return None
            self._asked = (list(points), [float(u) for u in horizons])
            if BUS.active:
                us = self._asked[1]
                BUS.emit("search.ask", round=self.state.round,
                         n=len(points), u_min=min(us), u_max=max(us),
                         warm=(0 if self._handles is None else
                               sum(1 for h in self._handles
                                   if h is not None)))
            return self._asked
        return None

    @property
    def resume_handles(self) -> "list[ResumeHandle | None] | None":
        """Per-point resume handles of the pending ask (or None when
        every lane starts cold)."""
        return self._handles

    @property
    def wants_states(self) -> bool:
        """Whether ``tell`` should receive the sweep's final lane states
        (:class:`~repro.dse.runner.LaneStates`).  Drivers that promote
        warm override this; the loop only pays the (already-transferred)
        state bookkeeping when someone will use it."""
        return False

    def tell(self, rows: Sequence[Mapping],
             states: LaneStates | None = None) -> None:
        """Feed back the result rows of the last ``ask``, in ask order.
        Records history + budget (each trial's *incremental* cycles —
        see :meth:`_trial_cycles` — also stored per trial under
        ``"cycles"``), lets the driver select/refit, advances the round
        counter and snapshots the RNG state (making this a valid resume
        point).  ``states`` carries the sweep's final lane states when
        the driver ``wants_states``."""
        assert self._asked is not None, "tell() without a pending ask()"
        points, horizons = self._asked
        assert len(rows) == len(points), (len(rows), len(points))
        tele = BUS.active
        costs = []
        for j, (u, row) in enumerate(zip(horizons, rows)):
            h = self._handles[j] if self._handles is not None else None
            cost = self._trial_cycles(u, row, h)
            trial = dict(row)
            trial["until"] = u
            trial["round"] = self.state.round
            trial["cycles"] = cost
            self.state.history.append(trial)
            self.state.budget += cost
            costs.append(cost)
            if tele:
                BUS.emit("trial", round=self.state.round, until=u,
                         cycles=cost, warm=h is not None,
                         value=self.objective.scalar(row), row=trial)
                BUS.count("search.trials")
        self._costs = costs
        self._tell(points, horizons, rows, states)
        if tele:
            best = self.best()
            BUS.emit("search.tell", round=self.state.round, n=len(rows),
                     cost=sum(costs), budget=self.state.budget,
                     cycle_budget=self.cycle_budget, best=best)
            BUS.gauge("search.budget", self.state.budget)
            if best is not None:
                BUS.gauge("search.best", self.objective.scalar(best))
        self._asked = None
        self._handles = None
        self._costs = None
        self.state.round += 1
        self.state.rng = self._rng.bit_generator.state

    @staticmethod
    def _trial_cycles(until: float, row: Mapping,
                      handle: ResumeHandle | None = None) -> float:
        """Simulated-cycle cost of one trial: the cycles it *newly* ran.

        A cold trial costs the cycles it actually simulated (a lane that
        drains early costs its own drain time, not the horizon), falling
        back to the horizon when the extractor does not report a usable
        ``virtual_time`` (a NaN would poison the cumulative budget and
        permanently disarm ``cycle_budget``).  A warm trial resumed from
        ``handle`` costs only the increment past the handle's frozen
        time — the whole point of state-resumed promotion: a config
        promoted up an entire horizon ladder costs its *final* virtual
        time, not the sum of every rung's replay.
        """
        try:
            v = float(row["virtual_time"])
        except (KeyError, TypeError, ValueError):
            v = float(until)
        if v != v:
            v = float(until)
        start = float(handle.time) if handle is not None else 0.0
        return max(v - start, 0.0)

    @property
    def done(self) -> bool:
        if (self.cycle_budget is not None
                and self.state.budget >= self.cycle_budget):
            return True
        return self._done()

    # -- subclass hooks ---------------------------------------------------
    def _ask(self) -> tuple[list[dict], list[float]] | None:
        raise NotImplementedError

    def _tell(self, points, horizons, rows,
              states: LaneStates | None = None) -> None:
        pass

    def _done(self) -> bool:
        raise NotImplementedError

    # -- results ----------------------------------------------------------
    @property
    def max_horizon(self) -> float:
        """The horizon at which trials are final (fully comparable to an
        exhaustive sweep).  Subclasses with a horizon ladder override."""
        hist = self.state.history
        return max((t["until"] for t in hist), default=0.0)

    def trials_at_max_horizon(self) -> list[dict]:
        return [t for t in self.state.history
                if t["until"] >= self.max_horizon]

    def best(self) -> dict | None:
        """The best trial: lowest scalarized objective among trials run
        to the full horizon (falling back to all of history when the
        budget cap stopped the search before any full-horizon round)."""
        pool = self.trials_at_max_horizon() or self.state.history
        if not pool:
            return None
        order = self.objective.order(pool)
        return pool[order[0]]

    def front(self) -> list[dict]:
        """The Pareto front over full-horizon trials (multi-objective);
        for a single objective this is just the best trial(s)."""
        pool = self.trials_at_max_horizon()
        return self.objective.front(pool) if pool else []

    def _draw_seed(self) -> int:
        """A child seed from the driver's persistent RNG stream (used
        for per-round candidate sampling; deterministic under resume)."""
        return int(self._rng.integers(0, 2**31 - 1))


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SearchResult:
    """What :func:`run_search` returns: the best trial, the running
    Pareto ``front`` (full-horizon trials), the full trial history
    (``rows``), the simulated-cycle ``budget`` spent, the number of
    ask/tell ``rounds`` executed, and the final resumable ``state``."""

    best: dict | None
    front: list[dict]
    rows: list[dict]
    budget: float
    rounds: int
    state: SearchState


def run_search(build_fn: Callable, driver: SearchDriver, *,
               extract: Callable | None = None,
               max_epochs: int = 2_000_000,
               chunk: int | None = None,
               schedule: ChunkSchedule | None = None,
               shard: "bool | int" = False,
               callback: Callable | None = None) -> SearchResult:
    """Drive a closed-loop search: ``ask`` → round-based sweep → ``tell``
    until the driver finishes.

    ``build_fn`` / ``extract`` / ``chunk`` / ``schedule`` / ``shard``
    mean exactly what they mean for :func:`~repro.dse.runner.run_sweep`
    — each round is one ``run_sweep`` call over the asked points at
    per-point horizons.  ``build_fn`` is memoized for the duration of
    the search (:func:`~repro.dse.runner.memoize_build`), so every
    round reuses one built simulation per static group — and therefore
    the shared :func:`~repro.dse.runner.runner_for` executables and the
    autotuned chunk ladder — instead of recompiling per round; pass an
    already-memoized build function to extend that reuse across
    searches.  ``callback(driver)`` fires after every ``tell`` (a valid
    :class:`SearchState` snapshot point).
    """
    build_fn = memoize_build(build_fn)
    if BUS.active:
        BUS.emit("search.start", driver=type(driver).__name__,
                 objective=driver.objective.objectives,
                 cycle_budget=driver.cycle_budget,
                 shard=_shard_devices(shard),
                 resumed_round=driver.state.round)
    rounds = 0
    while True:
        asked = driver.ask()
        if asked is None:
            break
        points, horizons = asked
        # the per-static-group key check stays on: a driver bug that
        # drops an axis key from some points fails here, naming the
        # point, not as an opaque stacking error inside the sweep
        spec = SweepSpec.explicit(points)
        want = driver.wants_states
        out = run_sweep(build_fn, spec,
                        until=np.asarray(horizons, np.float32),
                        extract=extract, chunk=chunk, schedule=schedule,
                        max_epochs=max_epochs, shard=shard,
                        resume=driver.resume_handles,
                        return_states=want)
        rows, states = out if want else (out, None)
        driver.tell(rows, states=states)
        rounds += 1
        if callback is not None:
            callback(driver)
    best = driver.best()
    if BUS.active:
        BUS.emit("search.end", rounds=rounds, budget=driver.state.budget,
                 trials=len(driver.state.history), best=best)
    return SearchResult(best=best, front=driver.front(),
                        rows=list(driver.state.history),
                        budget=driver.state.budget, rounds=rounds,
                        state=driver.state)
