"""Successive halving over round-based sweeps (ASHA-style).

Run the whole candidate pool at a short horizon, promote the top
``1/eta`` fraction to an ``eta``-times longer horizon, repeat until the
survivors reach the full horizon — the classic successive-halving
schedule (Jamieson & Talwalkar; ASHA), with the *horizon ladder* as the
fidelity axis: a rung-``r`` trial runs ``max_horizon / eta**(R-1-r)``
simulated cycles.  This is exactly the workload PR 4's per-lane ``until``
was built for: every rung is one mixed- or uniform-horizon
``run_sweep`` round, so promotion costs no recompiles and stragglers
cost no waste.

The horizon ladder is the *search* analogue of the runner's chunk
ladder (DSE.md): the chunk ladder schedules **wall-clock** (which lanes
share an executable in a round, result-invariant), the horizon ladder
schedules **simulated-cycle budget** (how long each config deserves to
run, the thing the search economizes).

``brackets > 1`` staggers Hyperband-style brackets: the pool is split
round-robin, bracket ``b`` starts ``b`` rungs up the ladder (fewer
configs, longer horizons), and every round asks all live brackets at
once — a genuinely mixed-horizon batch through one vmapped sweep.

Promotion ranks rows with :meth:`Objective.order` — single objectives
stably sort the scalarized column; multi-objective pools promote
non-dominated rows first (via :func:`~repro.dse.report.dominates`).
Rows are bit-reproducible and the sort is stable, so a seeded search's
trajectory is bit-reproducible and resumable (``state=``).

**Warm promotion** (``warm=True``, the default): a promoted config does
not replay from cycle 0 at the next rung — its rung-end
:class:`~repro.core.SimState` rides a
:class:`~repro.dse.runner.ResumeHandle` into the next round's stacked
batch and the lane simply *continues* to the longer horizon.  The
engine's epoch sequence is state-determined and ``until`` is an
absolute traced operand, so a resumed row is bit-identical to a cold
run at the same horizon (tests/dse/test_warm_resume.py) while the
budget is charged only the *increment*: a config promoted through the
whole ladder costs its final virtual time, not the sum of every rung's
replay (DSE.md "Warm-state promotions").  Rung states persist through
``repro.ckpt`` via :func:`~repro.dse.search.warm.save_search` /
:func:`~repro.dse.search.warm.load_search`, so a resumed search never
re-pays completed rungs either.
"""
from __future__ import annotations

import json
import math
from typing import Mapping, Sequence

from repro.obs.bus import BUS

from ..runner import LaneStates, ResumeHandle
from ..sweep import SweepSpec
from .driver import Objective, SearchDriver, SearchState


def horizon_ladder(max_horizon: float, min_horizon: float | None = None,
                   eta: int = 3, rungs: int | None = None) -> list[float]:
    """Geometric rung horizons ending exactly at ``max_horizon``.

    Either name the bottom (``min_horizon`` — the count of rungs is the
    largest R with ``max/eta**(R-1) >= min_horizon``) or the count
    (``rungs``).  Returns ``[max/eta**(R-1), ..., max/eta, max]``.
    """
    assert eta >= 2 and max_horizon > 0
    if rungs is None:
        if min_horizon is None:
            rungs = 1
        else:
            assert 0 < min_horizon <= max_horizon
            rungs = 1 + int(math.floor(
                math.log(max_horizon / min_horizon) / math.log(eta) + 1e-9))
    assert rungs >= 1
    return [max_horizon / eta ** (rungs - 1 - r) for r in range(rungs)]


class SuccessiveHalving(SearchDriver):
    """ASHA-style successive halving driving mixed-horizon sweep rounds.

    ``pool`` is the candidate set: a :class:`SweepSpec`, a sequence of
    point dicts, or an axes dict (as :meth:`SweepSpec.random` takes)
    sampled to ``n_init`` points with ``seed``.  Points may use any
    sweep axis — including ``shape.*`` family axes, so the search picks
    topology shapes as freely as latencies.

    The horizon ladder comes from ``max_horizon`` + (``min_horizon`` or
    ``rungs``) + ``eta`` (:func:`horizon_ladder`); each promotion keeps
    the top ``ceil(n / eta)`` of a rung.  ``brackets`` staggers
    Hyperband-style brackets (see module docstring).  ``cycle_budget``
    optionally hard-caps the simulated-cycle spend; ``bracket_budgets``
    additionally caps each bracket's *own* spend — ``"equal"`` splits
    ``cycle_budget`` evenly, or pass one explicit cap per bracket — so
    one expensive bracket can never starve its siblings.  Every bracket
    tracks its spend (``"spent"`` in the driver pocket) either way.

    ``warm=True`` (default) promotes by state-resume instead of replay
    (module docstring); ``warm=False`` restores the replay-from-zero
    behavior exactly (useful for A/B budget accounting, and for JSON-
    only resumes that cannot carry rung states).
    """

    def __init__(self, pool, objective: str | Mapping | Objective, *,
                 max_horizon: float, min_horizon: float | None = None,
                 rungs: int | None = None, eta: int = 3,
                 n_init: int | None = None, brackets: int = 1,
                 seed: int = 0, cycle_budget: float | None = None,
                 bracket_budgets: Sequence[float] | str | None = None,
                 warm: bool = True,
                 state: SearchState | None = None):
        super().__init__(objective, seed=seed, cycle_budget=cycle_budget,
                         state=state)
        if isinstance(pool, dict):
            assert n_init, "an axes-dict pool needs n_init"
            pool = SweepSpec.random(pool, n_init, seed=seed)
        points = [dict(p) for p in pool]
        assert points, "empty candidate pool"
        self.eta = int(eta)
        self.warm = bool(warm)
        self._handle_store: dict[str, ResumeHandle] = {}
        self.horizons = horizon_ladder(max_horizon, min_horizon, self.eta,
                                       rungs)
        n_brackets = max(1, min(int(brackets), len(self.horizons),
                                len(points)))
        if not self.state.driver:        # fresh search (not a resume)
            self.state.driver = {"brackets": [
                {"rung": b, "alive": points[b::n_brackets],
                 "spent": 0.0, "budget": None}
                for b in range(n_brackets)]}
        brs = self.state.driver["brackets"]
        if bracket_budgets is not None:
            if bracket_budgets == "equal":
                assert cycle_budget, \
                    "bracket_budgets='equal' needs a cycle_budget to split"
                caps = [float(cycle_budget) / len(brs)] * len(brs)
            else:
                caps = [float(c) for c in bracket_budgets]
                assert len(caps) == len(brs), (
                    f"{len(caps)} bracket budgets for {len(brs)} brackets")
            for br, cap in zip(brs, caps):
                br["budget"] = cap

    # ------------------------------------------------------------------
    @property
    def max_horizon(self) -> float:
        return self.horizons[-1]

    @property
    def wants_states(self) -> bool:
        return self.warm            # rung-end states feed the promotions

    def adopt_handles(self, handles: Mapping[str, ResumeHandle]) -> None:
        """Install rung-end resume handles restored from a checkpoint
        (:func:`~repro.dse.search.warm.load_search`): the resumed search
        continues warm instead of replaying its current rungs from
        cycle 0.  Without this, a JSON-only ``state=`` resume still
        produces identical rows — it just re-pays the replay cycles."""
        self._handle_store = dict(handles)

    @staticmethod
    def _hkey(bi: int, point: Mapping) -> str:
        """Handle-store key: bracket index + canonical point JSON (two
        brackets may carry the same point at different rungs)."""
        return f"{bi}|{json.dumps(point, sort_keys=True)}"

    @staticmethod
    def _bracket_live(br: dict) -> bool:
        cap = br.get("budget")
        return bool(br["alive"]) and (cap is None
                                      or br.get("spent", 0.0) < cap)

    def _live_brackets(self) -> list[dict]:
        return [br for br in self.state.driver["brackets"]
                if self._bracket_live(br)
                and br["rung"] < len(self.horizons)]

    def _done(self) -> bool:
        return not self._live_brackets()

    def _ask(self):
        points, horizons, handles = [], [], []
        segments = []
        for bi, br in enumerate(self.state.driver["brackets"]):
            if not (self._bracket_live(br)
                    and br["rung"] < len(self.horizons)):
                continue
            u = self.horizons[br["rung"]]
            for p in br["alive"]:
                points.append(dict(p))
                horizons.append(u)
                handles.append(self._handle_store.get(self._hkey(bi, p))
                               if self.warm else None)
            segments.append((bi, br, len(br["alive"])))
        self._segments = segments
        return points, horizons, handles

    def _tell(self, points, horizons, rows,
              states: LaneStates | None = None) -> None:
        lo = 0
        for bi, br, n in self._segments:
            seg = list(rows[lo:lo + n])
            seg_points = [dict(p) for p in points[lo:lo + n]]
            if self._costs is not None:   # per-bracket spend tracking
                br["spent"] = float(br.get("spent", 0.0)
                                    + sum(self._costs[lo:lo + n]))
            if self.warm:
                # this rung's handles are consumed: promoted points get
                # fresh rung-end states below, dropped points never run
                pref = f"{bi}|"
                for k in [k for k in self._handle_store
                          if k.startswith(pref)]:
                    del self._handle_store[k]
            last_rung = br["rung"] >= len(self.horizons) - 1
            if last_rung:
                keep, order = 0, []
                br["alive"] = []         # final rung: recorded, retired
            else:
                keep = max(1, math.ceil(n / self.eta))
                order = self.objective.order(seg)
                br["alive"] = [seg_points[i] for i in order[:keep]]
                if self.warm and states is not None:
                    for i in order[:keep]:
                        gi = lo + i
                        self._handle_store[
                            self._hkey(bi, seg_points[i])] = \
                            states.handle(gi, horizons[gi])
            if BUS.active:
                # warm-vs-cold cost: `spent` is what this rung actually
                # charged (warm lanes pay increments); `replay_cycles`
                # is what a replay-from-zero rung would have cost
                replay = 0.0
                for row in seg:
                    try:
                        replay += float(row.get("virtual_time",
                                                self.horizons[br["rung"]]))
                    except (TypeError, ValueError):
                        replay += float(self.horizons[br["rung"]])
                BUS.emit(
                    "rung.promote", bracket=bi, rung=br["rung"],
                    horizon=self.horizons[br["rung"]], n=n,
                    promoted=keep if not last_rung else 0,
                    dropped=n - keep if not last_rung else n,
                    warm=self.warm, final=last_rung,
                    spent=(float(sum(self._costs[lo:lo + n]))
                           if self._costs is not None else None),
                    replay_cycles=replay,
                    bracket_spent=br.get("spent", 0.0),
                    bracket_budget=br.get("budget"),
                    promoted_points=[seg_points[i] for i in order[:keep]]
                    [:8])
            br["rung"] += 1
            lo += n
        self._segments = None
