"""Successive halving over round-based sweeps (ASHA-style).

Run the whole candidate pool at a short horizon, promote the top
``1/eta`` fraction to an ``eta``-times longer horizon, repeat until the
survivors reach the full horizon — the classic successive-halving
schedule (Jamieson & Talwalkar; ASHA), with the *horizon ladder* as the
fidelity axis: a rung-``r`` trial runs ``max_horizon / eta**(R-1-r)``
simulated cycles.  This is exactly the workload PR 4's per-lane ``until``
was built for: every rung is one mixed- or uniform-horizon
``run_sweep`` round, so promotion costs no recompiles and stragglers
cost no waste.

The horizon ladder is the *search* analogue of the runner's chunk
ladder (DSE.md): the chunk ladder schedules **wall-clock** (which lanes
share an executable in a round, result-invariant), the horizon ladder
schedules **simulated-cycle budget** (how long each config deserves to
run, the thing the search economizes).

``brackets > 1`` staggers Hyperband-style brackets: the pool is split
round-robin, bracket ``b`` starts ``b`` rungs up the ladder (fewer
configs, longer horizons), and every round asks all live brackets at
once — a genuinely mixed-horizon batch through one vmapped sweep.

Promotion ranks rows with :meth:`Objective.order` — single objectives
stably sort the scalarized column; multi-objective pools promote
non-dominated rows first (via :func:`~repro.dse.report.dominates`).
Rows are bit-reproducible and the sort is stable, so a seeded search's
trajectory is bit-reproducible and resumable (``state=``).
"""
from __future__ import annotations

import math
from typing import Mapping

from ..sweep import SweepSpec
from .driver import Objective, SearchDriver, SearchState


def horizon_ladder(max_horizon: float, min_horizon: float | None = None,
                   eta: int = 3, rungs: int | None = None) -> list[float]:
    """Geometric rung horizons ending exactly at ``max_horizon``.

    Either name the bottom (``min_horizon`` — the count of rungs is the
    largest R with ``max/eta**(R-1) >= min_horizon``) or the count
    (``rungs``).  Returns ``[max/eta**(R-1), ..., max/eta, max]``.
    """
    assert eta >= 2 and max_horizon > 0
    if rungs is None:
        if min_horizon is None:
            rungs = 1
        else:
            assert 0 < min_horizon <= max_horizon
            rungs = 1 + int(math.floor(
                math.log(max_horizon / min_horizon) / math.log(eta) + 1e-9))
    assert rungs >= 1
    return [max_horizon / eta ** (rungs - 1 - r) for r in range(rungs)]


class SuccessiveHalving(SearchDriver):
    """ASHA-style successive halving driving mixed-horizon sweep rounds.

    ``pool`` is the candidate set: a :class:`SweepSpec`, a sequence of
    point dicts, or an axes dict (as :meth:`SweepSpec.random` takes)
    sampled to ``n_init`` points with ``seed``.  Points may use any
    sweep axis — including ``shape.*`` family axes, so the search picks
    topology shapes as freely as latencies.

    The horizon ladder comes from ``max_horizon`` + (``min_horizon`` or
    ``rungs``) + ``eta`` (:func:`horizon_ladder`); each promotion keeps
    the top ``ceil(n / eta)`` of a rung.  ``brackets`` staggers
    Hyperband-style brackets (see module docstring).  ``cycle_budget``
    optionally hard-caps the simulated-cycle spend.
    """

    def __init__(self, pool, objective: str | Mapping | Objective, *,
                 max_horizon: float, min_horizon: float | None = None,
                 rungs: int | None = None, eta: int = 3,
                 n_init: int | None = None, brackets: int = 1,
                 seed: int = 0, cycle_budget: float | None = None,
                 state: SearchState | None = None):
        super().__init__(objective, seed=seed, cycle_budget=cycle_budget,
                         state=state)
        if isinstance(pool, dict):
            assert n_init, "an axes-dict pool needs n_init"
            pool = SweepSpec.random(pool, n_init, seed=seed)
        points = [dict(p) for p in pool]
        assert points, "empty candidate pool"
        self.eta = int(eta)
        self.horizons = horizon_ladder(max_horizon, min_horizon, self.eta,
                                       rungs)
        n_brackets = max(1, min(int(brackets), len(self.horizons),
                                len(points)))
        if not self.state.driver:        # fresh search (not a resume)
            self.state.driver = {"brackets": [
                {"rung": b, "alive": points[b::n_brackets]}
                for b in range(n_brackets)]}

    # ------------------------------------------------------------------
    @property
    def max_horizon(self) -> float:
        return self.horizons[-1]

    def _live_brackets(self) -> list[dict]:
        return [br for br in self.state.driver["brackets"]
                if br["alive"] and br["rung"] < len(self.horizons)]

    def _done(self) -> bool:
        return not self._live_brackets()

    def _ask(self):
        points, horizons = [], []
        segments = []
        for br in self._live_brackets():
            u = self.horizons[br["rung"]]
            points += [dict(p) for p in br["alive"]]
            horizons += [u] * len(br["alive"])
            segments.append((br, len(br["alive"])))
        self._segments = segments
        return points, horizons

    def _tell(self, points, horizons, rows) -> None:
        lo = 0
        for br, n in self._segments:
            seg = list(rows[lo:lo + n])
            seg_points = [dict(p) for p in points[lo:lo + n]]
            lo += n
            last_rung = br["rung"] >= len(self.horizons) - 1
            if last_rung:
                br["alive"] = []         # final rung: recorded, retired
            else:
                keep = max(1, math.ceil(n / self.eta))
                order = self.objective.order(seg)
                br["alive"] = [seg_points[i] for i in order[:keep]]
            br["rung"] += 1
        self._segments = None
