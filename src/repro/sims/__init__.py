"""Case-study simulators built on the Akita engine (paper §4 and §5)."""
