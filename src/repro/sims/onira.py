"""Onira: an in-order RISC-V-style timing model on the engine (paper §5.1).

Five-stage-pipeline timing semantics (single issue, full forwarding,
1-cycle load-use stall via a register scoreboard, 2-cycle taken-branch
flush, non-blocking loads with a 4-entry load queue, one outstanding
store), attached to a memory component over a latency-L connection — the
paper's "single core, 5-cycle memory latency" setup.

The ISA is a micro-subset sufficient for the paper's microbenchmarks:
  ADDI rd, rs1, imm   (op=1)      LOAD rd, [rs1]     (op=2)
  STORE [rs1], rd     (op=3)      BNEZ rs1, +imm     (op=4; taken if !=0)
  HALT                (op=5)
Accuracy is validated against closed-form pipeline CPI (our stand-in for
the paper's Verilator RTL, which is unavailable offline — DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ComponentKind, SimBuilder, TickResult, msg_new,
                        msg_reply, oh_set, payload)

ADDI, LOAD, STORE, BNEZ, HALT = 1, 2, 3, 4, 5
MAXI = 128

# Sweepable CPU timing params (traced; DSE.md): the taken-branch flush
# penalty in cycles.  Memory latency sweeps ride the cpu<->mem connection
# latency axis.  Defaults reproduce the unparameterized model bit-for-bit.
CPU_PARAMS = {"flush_cycles": jnp.float32(3.0)}


def cpu_tick(state, ports, t, params):
    state = dict(state)
    progress = jnp.asarray(False)
    # load response: p1 = destination register
    msg, got, ports = ports.recv(0)
    reg = payload(msg, 1)
    state["busy"] = oh_set(state["busy"], reg, 0, when=got)
    state["pending"] = state["pending"] - got.astype(jnp.int32)
    progress = progress | got

    halted = state["done"] > 0
    flushing = t + 1e-3 < state["stall_until"]
    pc = jnp.clip(state["pc"], 0, MAXI - 1)
    inst = state["prog"][pc]                       # [4]
    op, rd, rs1, imm = inst[0], inst[1], inst[2], inst[3]
    can_issue = ~halted & ~flushing

    src_busy = state["busy"][rs1] > 0
    dst_busy = state["busy"][rd] > 0               # stores read rd as data

    # ALU
    do_alu = can_issue & (op == ADDI) & ~src_busy
    state["regs"] = oh_set(state["regs"], rd, state["regs"][rs1] + imm,
                           when=do_alu)
    # LOAD
    can_load = can_issue & (op == LOAD) & ~src_busy & \
        (state["pending"] < 4) & ports.can_send(0)
    ports, sent_l = ports.send(
        0, msg_new(1, p0=state["regs"][rs1], p1=rd), when=can_load)
    state["busy"] = oh_set(state["busy"], rd, 1, when=sent_l)
    state["pending"] = state["pending"] + sent_l.astype(jnp.int32)
    # STORE (fire-and-forget, but bounded by buffer space)
    can_store = can_issue & (op == STORE) & ~src_busy & ~dst_busy & \
        ports.can_send(0)
    ports, sent_s = ports.send(
        0, msg_new(3, p0=state["regs"][rs1], p1=32), when=can_store)
    # BRANCH (resolve in EX: 2-cycle flush when taken)
    do_br = can_issue & (op == BNEZ) & ~src_busy
    taken = do_br & (state["regs"][rs1] != 0)
    # HALT
    do_halt = can_issue & (op == HALT)
    state["done"] = jnp.where(do_halt, 1, state["done"])
    state["halt_time"] = jnp.where(do_halt, t, state["halt_time"])

    issued = do_alu | sent_l | sent_s | do_br | do_halt
    state["pc"] = jnp.where(
        issued, jnp.where(taken, pc + imm, pc + 1), state["pc"])
    state["retired"] = state["retired"] + issued.astype(jnp.int32)
    state["stall_until"] = jnp.where(taken, t + params["flush_cycles"],
                                     state["stall_until"])
    # load-use stall bookkeeping (pure accounting)
    state["stalls"] = state["stalls"] + \
        (can_issue & ~issued).astype(jnp.int32)
    progress = progress | issued
    nxt = jnp.where(flushing & ~halted, state["stall_until"], -1.0)
    return state, ports, TickResult.make(progress | flushing, next_time=nxt)


def mem_tick(state, ports, t):
    state = dict(state)
    msg, got, ports = ports.recv(0, when=ports.can_send(0))
    is_read = got & (msg[0] == 1)
    ports, _ = ports.send(0, msg_reply(msg, 2, p0=payload(msg, 0),
                                       p1=payload(msg, 1)), when=is_read)
    state["served"] = state["served"] + got.astype(jnp.int32)
    return state, ports, TickResult.make(got)


# ---------------------------------------------------------------------------
# assembler + microbenchmarks (paper Fig. 12/13)
# ---------------------------------------------------------------------------
def asm(instrs):
    p = np.zeros((MAXI, 4), np.int32)
    for i, ins in enumerate(instrs):
        p[i] = ins + [0] * (4 - len(ins))
    return p


def prog_alu(n=64):
    return asm([[ADDI, 1, 1, 1] for _ in range(n)] + [[HALT]])


def prog_raw_hzd(n=32):
    # load-use chains: LOAD r2,[r1]; ADDI r3,r2,1 (stalls full latency)
    body = []
    for _ in range(n):
        body += [[LOAD, 2, 1, 0], [ADDI, 3, 2, 1]]
    return asm(body + [[HALT]])


def prog_br_loop(iters=16, body_n=4):
    # r5 = iters; loop: body_n ALUs; ADDI r5,r5,-1; BNEZ r5, -body_n-1
    pre = [[ADDI, 5, 0, iters]]
    body = [[ADDI, 1, 1, 1] for _ in range(body_n)]
    loop = body + [[ADDI, 5, 5, -1], [BNEZ, 5, 5, -(body_n + 1)]]
    return asm(pre + loop + [[HALT]])


def prog_nested_br(outer=4, inner=4):
    pre = [[ADDI, 5, 0, outer]]
    inner_l = [[ADDI, 6, 0, inner], [ADDI, 1, 1, 1], [ADDI, 6, 6, -1],
               [BNEZ, 6, 6, -2]]
    outer_l = inner_l + [[ADDI, 5, 5, -1], [BNEZ, 5, 5, -(len(inner_l) + 1)]]
    return asm(pre + outer_l + [[HALT]])


def prog_st_ld(n=16):
    body = []
    for _ in range(n):
        body += [[STORE, 1, 1, 0], [LOAD, 2, 1, 0], [ADDI, 3, 2, 1]]
    return asm(body + [[HALT]])


def prog_conc_st(n=32):
    return asm([[STORE, 1, 1, 0] for _ in range(n)] + [[HALT]])


def prog_ind_ld(n=32):
    # independent loads into rotating registers (no use: MLP-friendly)
    return asm([[LOAD, 2 + (i % 4), 1, 0] for i in range(n)] + [[HALT]])


def prog_mlp(n_indep: int, reps=None):
    reps = reps or max(1, min(8, (MAXI - 1) // (2 * n_indep)))
    body = []
    for _ in range(reps):
        for i in range(n_indep):
            body.append([LOAD, 2 + (i % 28), 1, 0])
        for i in range(n_indep):
            body.append([ADDI, 1, 2 + (i % 28), 0])  # consume
    return asm(body + [[HALT]])


MICROBENCHES = {
    "ALU": prog_alu, "RAW_HZD": prog_raw_hzd, "BR_LOOP": prog_br_loop,
    "LOOP1": lambda: prog_br_loop(iters=32, body_n=1),
    "NESTED_BR": prog_nested_br, "ST_LD": prog_st_ld,
    "CONC_ST": prog_conc_st, "IND_LD": prog_ind_ld,
}


def build_onira(progs: list[np.ndarray], mem_latency: float = 5.0,
                naive: bool = False):
    n = len(progs)
    b = SimBuilder()
    cpu = b.add_kind(ComponentKind(
        "cpu", cpu_tick, n, 1,
        {"prog": jnp.asarray(np.stack(progs)),
         "pc": jnp.zeros(n, jnp.int32),
         "regs": jnp.zeros((n, 33), jnp.int32),
         "busy": jnp.zeros((n, 33), jnp.int32),
         "pending": jnp.zeros(n, jnp.int32),
         "retired": jnp.zeros(n, jnp.int32),
         "stalls": jnp.zeros(n, jnp.int32),
         "done": jnp.zeros(n, jnp.int32),
         "halt_time": jnp.zeros(n, jnp.float32),
         "stall_until": jnp.zeros(n, jnp.float32)}, cap=4,
        params=CPU_PARAMS))
    mem = b.add_kind(ComponentKind(
        "mem", mem_tick, n, 1, {"served": jnp.zeros(n, jnp.int32)}, cap=4))
    for i in range(n):
        b.connect([cpu.port(i, 0), mem.port(i, 0)], latency=mem_latency)
    sim = b.build(naive=naive)
    return sim, sim.init_state()


def build_onira_family(progs: list[np.ndarray], mem_latency: float = 5.0,
                       shape=None, naive: bool = False):
    """The onira topology family: up to ``len(progs)`` CPU+memory pairs.

    One padded build (``pad_shape`` sizes the cpu/mem segments to the
    family maximum) runs any prefix of the program list via activity
    masks — the ``shape.cpu`` axis sweeps how many pipelines are live
    without recompiling, and each masked run is bit-identical on active
    rows to ``build_onira(progs[:n])`` (pinned by
    ``tests/dse/test_structural.py``).

    Returns a :class:`repro.dse.TopologyFamily` with shape axis ``cpu``.
    """
    from repro.dse.family import TopologyFamily

    n_max = len(progs)
    if shape:
        # size the family to the sweep's maximum (must fit the programs)
        n_max = int(shape.get("cpu", n_max))
        assert n_max <= len(progs), (n_max, len(progs))
    b = SimBuilder()
    cpu = b.add_kind(ComponentKind(
        "cpu", cpu_tick, 1, 1,
        {"prog": jnp.zeros((1, MAXI, 4), jnp.int32),
         "pc": jnp.zeros(1, jnp.int32),
         "regs": jnp.zeros((1, 33), jnp.int32),
         "busy": jnp.zeros((1, 33), jnp.int32),
         "pending": jnp.zeros(1, jnp.int32),
         "retired": jnp.zeros(1, jnp.int32),
         "stalls": jnp.zeros(1, jnp.int32),
         "done": jnp.zeros(1, jnp.int32),
         "halt_time": jnp.zeros(1, jnp.float32),
         "stall_until": jnp.zeros(1, jnp.float32)}, cap=4,
        params=CPU_PARAMS))
    mem = b.add_kind(ComponentKind(
        "mem", mem_tick, 1, 1, {"served": jnp.zeros(1, jnp.int32)}, cap=4))
    for i in range(n_max):
        b.connect([cpu.port(i, 0), mem.port(i, 0)], latency=mem_latency)
    sim = b.build(naive=naive,
                  pad_shape={"cpu": n_max, "mem": n_max})

    def state_fn(shape_d):
        n = int(shape_d["cpu"])
        prog = np.zeros((n_max, MAXI, 4), np.int32)
        prog[:n] = np.stack(progs[:n])
        st = sim.init_state()
        cs = dict(st.comp_state)
        cs["cpu"] = dict(cs["cpu"], prog=prog)
        return dataclasses.replace(
            st, comp_state=jax.tree.map(jnp.asarray, cs))

    return TopologyFamily(
        sim=sim, shape_max={"cpu": n_max},
        kind_counts=lambda s: {"cpu": s["cpu"], "mem": s["cpu"]},
        state_fn=state_fn)


def run_microbenches(names=None, mem_latency=5.0, until=20000.0):
    names = names or list(MICROBENCHES)
    progs = [MICROBENCHES[n]() for n in names]
    sim, st = build_onira(progs, mem_latency)
    out = sim.run(st, until=until)
    cs = out.comp_state["cpu"]
    res = {}
    for i, n in enumerate(names):
        insts = int(cs["retired"][i])
        cycles = float(cs["halt_time"][i])
        res[n] = {"insts": insts, "cycles": cycles,
                  "cpi": cycles / max(insts, 1),
                  "done": bool(cs["done"][i])}
    return res


def run_mlp_sweep(n_values=(1, 2, 4, 8, 16), mem_latency=5.0):
    progs = [prog_mlp(n) for n in n_values]
    sim, st = build_onira(progs, mem_latency)
    out = sim.run(st, until=50000.0)
    cs = out.comp_state["cpu"]
    return {n: float(cs["halt_time"][i]) / max(int(cs["retired"][i]), 1)
            for i, n in enumerate(n_values)}


# Closed-form pipeline reference (our RTL stand-in; DESIGN.md §7)
def analytic_cpi(name: str, mem_latency: float = 5.0) -> float:
    L = mem_latency + 1  # + request wire cycle
    if name == "ALU":
        return 1.0
    if name == "RAW_HZD":
        # per pair: LOAD issues, ADDI waits full round-trip (2L), then 1
        return (1 + 2 * L + 1) / 2
    if name in ("BR_LOOP", "LOOP1"):
        body = 4 if name == "BR_LOOP" else 1
        per_iter = body + 2 + 2  # insts + dec/bnez + flush
        return per_iter / (body + 2)
    if name == "NESTED_BR":
        return 1.6  # mixed flushes, approximate
    if name == "ST_LD":
        return (3 + 2 * L) / 3  # ld-use exposed each triple
    if name == "CONC_ST":
        # fire-and-forget through a 4-deep buffer drained 1/cycle after L
        return 1.25
    if name == "IND_LD":
        # 4-entry load queue, round trip = L (req) + 1 (service) + L (resp)
        return (2 * mem_latency + 1) / 4
    raise KeyError(name)
