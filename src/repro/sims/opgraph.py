"""Operator-level trace generation for TrioSim (paper §5.2).

Converts any assigned (arch config × shape) plus a parallelism plan
(DP/TP/PP) into per-device operator lists: COMPUTE (estimated from the
roofline cost model, standing in for the paper's single-GPU trace
measurements), COLL (ring collectives) and P2P (pipeline stage handoffs).

Op encoding (int32 rows): [kind, size_kb_or_us, tag, peer]
  kind: 0=DONE 1=COMPUTE(size=duration µs) 2=COLL(size=KB, tag)
        3=P2P_SEND(size=KB, tag, peer) 4=P2P_RECV(tag)
"""
from __future__ import annotations

import dataclasses

import numpy as np

DONE, COMPUTE, COLL, P2P_SEND, P2P_RECV = 0, 1, 2, 3, 4


@dataclasses.dataclass
class HW:
    flops: float = 70e12          # per device (A40-class bf16 dense)
    hbm: float = 696e9
    link_bw: float = 25e9         # per-direction interconnect
    coll_alpha_us: float = 10.0   # per-step latency


def _us(flops, bytes_, hw: HW) -> float:
    return max(flops / hw.flops, bytes_ / hw.hbm) * 1e6


def build_train_trace(cfg, batch: int, seq: int, dp: int, tp: int, pp: int,
                      micro: int = 4, hw: HW = HW()):
    """Returns (ops [n_dev, MAX, 4] int32, n_tags). Device grid: dp×pp×tp
    (tp innermost)."""
    n_dev = dp * tp * pp
    P = cfg.param_count()
    L = cfg.n_layers
    stages = [L // pp + (1 if s < L % pp else 0) for s in range(pp)]
    tokens = batch * seq // dp // max(micro, 1)      # per microbatch per dp
    p_layer = (P - 2 * cfg.vocab * cfg.d_model) / L
    act_kb = tokens * cfg.d_model * 2 / 1024

    tag = [0]

    def next_tag():
        tag[0] += 1
        return tag[0] - 1

    devs = [[] for _ in range(n_dev)]

    def dev(d, s, t):
        return (d * pp + s) * tp + t

    # microbatch pipeline: fwd then bwd (GPipe flush schedule)
    for d in range(dp):
        fwd_tags: dict = {}
        bwd_tags: dict = {}
        for m in range(micro):
            for s in range(pp):
                coll_tag = next_tag() if tp > 1 else -1  # shared across tp
                for t in range(tp):
                    ops = devs[dev(d, s, t)]
                    if s > 0:
                        tg = fwd_tags.setdefault((m, s, t), next_tag())
                        ops.append([P2P_RECV, 0, tg, dev(d, s - 1, t)])
                    fl = 2 * p_layer * stages[s] * tokens / tp
                    by = p_layer * stages[s] * 2 / tp
                    ops.append([COMPUTE, int(_us(fl, by, hw)) + 1, 0, 0])
                    if tp > 1:   # TP activation allreduce per stage
                        ops.append([COLL, int(act_kb) + 1, coll_tag, tp])
                    if s < pp - 1:
                        tg = fwd_tags.setdefault((m, s + 1, t), next_tag())
                        ops.append([P2P_SEND, int(act_kb) + 1, tg,
                                    dev(d, s + 1, t)])
        for m in range(micro):
            for s in reversed(range(pp)):
                coll_tag = next_tag() if tp > 1 else -1
                for t in range(tp):
                    ops = devs[dev(d, s, t)]
                    if s < pp - 1:
                        tg = bwd_tags.setdefault((m, s, t), next_tag())
                        ops.append([P2P_RECV, 0, tg, dev(d, s + 1, t)])
                    fl = 4 * p_layer * stages[s] * tokens / tp
                    by = 2 * p_layer * stages[s] * 2 / tp
                    ops.append([COMPUTE, int(_us(fl, by, hw)) + 1, 0, 0])
                    if tp > 1:
                        ops.append([COLL, int(act_kb) + 1, coll_tag, tp])
                    if s > 0:
                        tg = bwd_tags.setdefault((m, s - 1, t), next_tag())
                        ops.append([P2P_SEND, int(act_kb) + 1, tg,
                                    dev(d, s - 1, t)])
    # DP gradient allreduce (per stage×tp slice, across dp)
    if dp > 1:
        for s in range(pp):
            for t in range(tp):
                tg = next_tag()
                grad_kb = p_layer * stages[s] * 2 / tp / 1024
                for d in range(dp):
                    devs[dev(d, s, t)].append([COLL, int(grad_kb) + 1, tg,
                                               dp])
    for ops in devs:
        ops.append([DONE, 0, 0, 0])
    mx = max(len(o) for o in devs)
    arr = np.zeros((n_dev, mx, 4), np.int32)
    for i, o in enumerate(devs):
        arr[i, :len(o)] = np.asarray(o, np.int32)
    return arr, tag[0]


def analytic_step_us(cfg, batch, seq, dp, tp, pp, micro, hw: HW = HW()):
    """Closed-form lower bound (no overlap): compute + TP coll + DP coll +
    pipeline bubble factor."""
    P = cfg.param_count()
    p_layer = (P - 2 * cfg.vocab * cfg.d_model) / cfg.n_layers
    L = cfg.n_layers
    tokens = batch * seq // dp
    comp = 6 * p_layer * L * tokens / tp / pp / hw.flops * 1e6
    bubble = (pp - 1) / max(micro, 1)      # GPipe flush bubble
    comp *= (1 + bubble)
    act_b = tokens // max(micro, 1) * cfg.d_model * 2
    tp_coll = 0.0
    if tp > 1:
        # trace aggregates one collective per (microbatch, direction, stage)
        n_coll = 2 * max(micro, 1)
        tp_coll = n_coll * (2 * (tp - 1) / tp * act_b / hw.link_bw * 1e6
                            + hw.coll_alpha_us)
    dp_coll = 0.0
    if dp > 1:
        grad_b = p_layer * L / pp * 2 / tp
        dp_coll = (2 * (dp - 1) / dp * grad_b / hw.link_bw * 1e6
                   + hw.coll_alpha_us)
    return comp + tp_coll + dp_coll
