"""First-party component library (paper §3: "Akita ships with a wide range
of first-party components, including caches with different write policies,
DRAM modules, TLBs and MMUs, on-chip and off-chip network models").

Every component is a plain ``tick_fn`` against the engine's port protocol —
the protocol-first, open-closed design of DX-1a/DX-1b: policies (write-back
vs write-through, row-buffer management, translation latencies) are
constructor parameters, not code edits.

Protocol opcodes (shared with memsys):
  1 READ_REQ  (p0=addr, p1=tag)     2 READ_RESP (p0=addr, p1=tag)
  3 WRITE_REQ (p0=addr, p1=tag)     4 WRITE_ACK (p0=addr, p1=tag)
  5 XLAT_REQ  (p0=vaddr, p1=tag)    6 XLAT_RESP (p0=paddr, p1=tag)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (ComponentKind, TickResult, msg_new, msg_reply,
                        opcode, payload)

READ_REQ, READ_RESP, WRITE_REQ, WRITE_ACK = 1, 2, 3, 4
XLAT_REQ, XLAT_RESP = 5, 6
PAGE = 4096
LINE = 64


# ---------------------------------------------------------------------------
# Cache with configurable write policy (write-through / write-back)
# ---------------------------------------------------------------------------
def make_cache_kind(name: str, n: int, n_sets: int = 64,
                    write_back: bool = True, cap: int = 2) -> ComponentKind:
    """Direct-mapped cache; port 0 = upstream (CPU side), port 1 =
    downstream (memory side).  Write-back keeps dirty bits and evicts with a
    WRITE_REQ; write-through forwards every write immediately."""

    def tick(state, ports, t):
        state = dict(state)
        progress = jnp.asarray(False)

        # downstream fill response
        rmsg, rgot, ports = ports.recv(1, when=ports.can_send(0)
                                       & ports.can_send(1))
        r_is_read = rgot & (opcode(rmsg) == READ_RESP)
        addr_r = payload(rmsg, 0)
        set_r = (addr_r // LINE) % n_sets
        # write-back eviction of the victim line
        victim_dirty = r_is_read & (state["dirty"][set_r] > 0) & \
            (state["tags"][set_r] >= 0)
        ev_addr = state["tags"][set_r] * LINE
        ports, _ = ports.send(1, msg_new(WRITE_REQ, p0=ev_addr, p1=9999),
                              when=victim_dirty & jnp.asarray(write_back))
        state["tags"] = jnp.where(
            r_is_read, state["tags"].at[set_r].set(addr_r // LINE),
            state["tags"])
        state["dirty"] = jnp.where(
            r_is_read, state["dirty"].at[set_r].set(0), state["dirty"])
        ports, _ = ports.send(0, msg_new(READ_RESP, p0=addr_r,
                                         p1=payload(rmsg, 1)), when=r_is_read)
        state["mshr"] = jnp.where(r_is_read, 0, state["mshr"])
        progress = progress | rgot

        # upstream request
        msg, got = ports.peek(0)
        op = opcode(msg)
        addr = payload(msg, 0)
        set_i = (addr // LINE) % n_sets
        hit = state["tags"][set_i] == addr // LINE
        is_rd, is_wr = op == READ_REQ, op == WRITE_REQ
        can_rd_hit = is_rd & hit & ports.can_send(0)
        can_rd_miss = is_rd & ~hit & (state["mshr"] == 0) & ports.can_send(1)
        wb = jnp.asarray(write_back)
        # write policy: WB hits set dirty; WT forwards downstream
        can_wr_hit = is_wr & hit & (wb | ports.can_send(1)) & \
            ports.can_send(0)
        can_wr_miss = is_wr & ~hit & ports.can_send(1) & ports.can_send(0)
        accept = got & (can_rd_hit | can_rd_miss | can_wr_hit | can_wr_miss)
        _, _, ports = ports.recv(0, when=accept)

        ports, _ = ports.send(0, msg_new(READ_RESP, p0=addr,
                                         p1=payload(msg, 1)),
                              when=accept & can_rd_hit)
        ports, fwd = ports.send(1, msg_new(READ_REQ, p0=addr,
                                           p1=payload(msg, 1)),
                                when=accept & can_rd_miss)
        state["mshr"] = jnp.where(fwd, 1, state["mshr"])
        state["dirty"] = jnp.where(
            accept & can_wr_hit & wb, state["dirty"].at[set_i].set(1),
            state["dirty"])
        ports, _ = ports.send(1, msg_new(WRITE_REQ, p0=addr,
                                         p1=payload(msg, 1)),
                              when=accept & ((can_wr_hit & ~wb)
                                             | can_wr_miss))
        ports, _ = ports.send(0, msg_new(WRITE_ACK, p0=addr,
                                         p1=payload(msg, 1)),
                              when=accept & (can_wr_hit | can_wr_miss))
        state["hits"] = state["hits"] + (accept & hit).astype(jnp.int32)
        state["misses"] = state["misses"] + (accept & ~hit).astype(jnp.int32)
        state["writes"] = state["writes"] + (accept & is_wr).astype(jnp.int32)
        progress = progress | accept
        return state, ports, TickResult.make(progress)

    return ComponentKind(name, tick, n, 2, {
        "tags": jnp.full((n, n_sets), -1, jnp.int32),
        "dirty": jnp.zeros((n, n_sets), jnp.int32),
        "mshr": jnp.zeros(n, jnp.int32),
        "hits": jnp.zeros(n, jnp.int32),
        "misses": jnp.zeros(n, jnp.int32),
        "writes": jnp.zeros(n, jnp.int32)}, cap=cap)


# ---------------------------------------------------------------------------
# TLB (one level) and MMU (page-table walker)
# ---------------------------------------------------------------------------
def make_tlb_kind(name: str, n: int, entries: int = 16,
                  cap: int = 2) -> ComponentKind:
    """Port 0 = upstream (translation requests), port 1 = downstream
    (next TLB level / MMU).  Direct-mapped on virtual page number."""

    def tick(state, ports, t):
        state = dict(state)
        progress = jnp.asarray(False)
        rmsg, rgot, ports = ports.recv(1, when=ports.can_send(0))
        r_ok = rgot & (opcode(rmsg) == XLAT_RESP)
        vpn_r = state["pending_vpn"]
        state["vtags"] = jnp.where(
            r_ok, state["vtags"].at[vpn_r % entries].set(vpn_r),
            state["vtags"])
        state["ptags"] = jnp.where(
            r_ok, state["ptags"].at[vpn_r % entries].set(payload(rmsg, 0)),
            state["ptags"])
        ports, _ = ports.send(0, msg_new(XLAT_RESP, p0=payload(rmsg, 0),
                                         p1=payload(rmsg, 1)), when=r_ok)
        state["busy"] = jnp.where(r_ok, 0, state["busy"])
        progress = progress | rgot

        msg, got = ports.peek(0)
        vaddr = payload(msg, 0)
        vpn = vaddr // PAGE
        hit = state["vtags"][vpn % entries] == vpn
        can_hit = hit & ports.can_send(0)
        can_miss = ~hit & (state["busy"] == 0) & ports.can_send(1)
        accept = got & (opcode(msg) == XLAT_REQ) & (can_hit | can_miss)
        _, _, ports = ports.recv(0, when=accept)
        paddr = state["ptags"][vpn % entries]
        ports, _ = ports.send(0, msg_new(XLAT_RESP, p0=paddr,
                                         p1=payload(msg, 1)),
                              when=accept & can_hit)
        ports, fwd = ports.send(1, msg_new(XLAT_REQ, p0=vaddr,
                                           p1=payload(msg, 1)),
                                when=accept & can_miss)
        state["busy"] = jnp.where(fwd, 1, state["busy"])
        state["pending_vpn"] = jnp.where(fwd, vpn, state["pending_vpn"])
        state["hits"] = state["hits"] + (accept & hit).astype(jnp.int32)
        state["misses"] = state["misses"] + fwd.astype(jnp.int32)
        progress = progress | accept
        return state, ports, TickResult.make(progress)

    return ComponentKind(name, tick, n, 2, {
        "vtags": jnp.full((n, entries), -1, jnp.int32),
        "ptags": jnp.zeros((n, entries), jnp.int32),
        "busy": jnp.zeros(n, jnp.int32),
        "pending_vpn": jnp.zeros(n, jnp.int32),
        "hits": jnp.zeros(n, jnp.int32),
        "misses": jnp.zeros(n, jnp.int32)}, cap=cap)


def make_mmu_kind(name: str, n: int, walk_latency: float = 20.0,
                  max_vpn: int = 1 << 16, cap: int = 4) -> ComponentKind:
    """Page-table walker: identity-maps VPN->PPN after ``walk_latency``
    cycles; VPNs >= max_vpn fault (drop + count — the paper's Fig-6 'Page
    entry not found' scenario is raised host-side by the driver)."""

    def tick(state, ports, t):
        state = dict(state)
        progress = jnp.asarray(False)
        # finish an in-flight walk
        fin = (state["busy"] > 0) & (t + 1e-3 >= state["done_at"]) & \
            ports.can_send(0)
        ports, _ = ports.send(0, msg_new(
            XLAT_RESP, p0=state["walk_vpn"] * PAGE + 0x1000,
            p1=state["walk_tag"]), when=fin)
        state["busy"] = jnp.where(fin, 0, state["busy"])
        state["walks"] = state["walks"] + fin.astype(jnp.int32)
        progress = progress | fin
        # accept a new walk
        msg, got = ports.peek(0)
        vpn = payload(msg, 0) // PAGE
        fault = vpn >= max_vpn
        accept = got & (opcode(msg) == XLAT_REQ) & (state["busy"] == 0)
        _, _, ports = ports.recv(0, when=accept)
        state["faults"] = state["faults"] + \
            (accept & fault).astype(jnp.int32)
        start = accept & ~fault
        state["busy"] = jnp.where(start, 1, state["busy"])
        state["walk_vpn"] = jnp.where(start, vpn, state["walk_vpn"])
        state["walk_tag"] = jnp.where(start, payload(msg, 1),
                                      state["walk_tag"])
        state["done_at"] = jnp.where(start, t + walk_latency,
                                     state["done_at"])
        progress = progress | accept
        nxt = jnp.where(state["busy"] > 0, state["done_at"], -1.0)
        return state, ports, TickResult.make(progress, next_time=nxt)

    return ComponentKind(name, tick, n, 1, {
        "busy": jnp.zeros(n, jnp.int32),
        "walk_vpn": jnp.zeros(n, jnp.int32),
        "walk_tag": jnp.zeros(n, jnp.int32),
        "done_at": jnp.zeros(n, jnp.float32),
        "walks": jnp.zeros(n, jnp.int32),
        "faults": jnp.zeros(n, jnp.int32)}, cap=cap)


# ---------------------------------------------------------------------------
# Banked DRAM with a row-buffer model (DRAMSim-flavoured timing)
# ---------------------------------------------------------------------------
def make_dram_kind(name: str, n: int, n_banks: int = 8, row_bits: int = 11,
                   t_cas: float = 4.0, t_rcd: float = 8.0,
                   t_rp: float = 8.0, cap: int = 8) -> ComponentKind:
    """Row-buffer hits cost CAS; closed rows cost RP+RCD+CAS.  One request
    per tick; per-bank open-row state."""

    def tick(state, ports, t):
        state = dict(state)
        msg, got, ports = ports.recv(0, when=ports.can_send(0))
        op = opcode(msg)
        addr = payload(msg, 0)
        bank = (addr // LINE) % n_banks
        row = addr >> row_bits
        open_row = state["open_row"][bank]
        row_hit = open_row == row
        lat = jnp.where(row_hit, t_cas,
                        jnp.where(open_row < 0, t_rcd + t_cas,
                                  t_rp + t_rcd + t_cas))
        state["open_row"] = jnp.where(
            got, state["open_row"].at[bank].set(row), state["open_row"])
        state["row_hits"] = state["row_hits"] + \
            (got & row_hit).astype(jnp.int32)
        state["served"] = state["served"] + got.astype(jnp.int32)
        # service time is modeled as a deferred reply (event-driven)
        is_read = got & (op == READ_REQ)
        ports, _ = ports.send(0, msg_reply(msg, READ_RESP, p0=addr,
                                           p1=payload(msg, 1)), when=is_read)
        # NB: latency variation is modeled by the bank's busy window; a
        # fully-timed variant would defer the send via next_time — kept
        # simple so the reply latency = connection latency + lat is folded
        # into stats (see test for row-hit accounting).
        state["busy_cycles"] = state["busy_cycles"] + \
            jnp.where(got, lat, 0.0)
        return state, ports, TickResult.make(got)

    return ComponentKind(name, tick, n, 1, {
        "open_row": jnp.full((n, n_banks), -1, jnp.int32),
        "row_hits": jnp.zeros(n, jnp.int32),
        "served": jnp.zeros(n, jnp.int32),
        "busy_cycles": jnp.zeros(n, jnp.float32)}, cap=cap)
