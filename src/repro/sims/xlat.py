"""Address-translation case study — the paper's Fig. 6 scenario.

A requester core drives loads through L1 TLB -> L2 TLB -> MMU (page-table
walker) built from the first-party component library.  A virtual address
beyond the mapped region raises the paper's "Page entry not found" panic,
and the enhanced backtrace prints the architectural cause chain
(instruction -> translation -> L1TLB -> L2TLB -> MMU) instead of a bare
Python stack.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (ComponentKind, SimBuilder, TickResult, msg_new,
                        opcode, payload)
from repro.core.tracing import TracingDomain, format_backtrace

from .components import (PAGE, XLAT_REQ, XLAT_RESP, make_mmu_kind,
                         make_tlb_kind)


def requester_tick(state, ports, t):
    state = dict(state)
    progress = jnp.asarray(False)
    msg, got, ports = ports.recv(0)
    state["outstanding"] = state["outstanding"] - got.astype(jnp.int32)
    state["translated"] = state["translated"] + got.astype(jnp.int32)
    state["last_paddr"] = jnp.where(got, payload(msg, 0),
                                    state["last_paddr"])
    progress = progress | got
    idx = state["issued"]
    want = (idx < state["n_addrs"]) & (state["outstanding"] < 2)
    vaddr = state["addrs"][jnp.clip(idx, 0, state["addrs"].shape[0] - 1)]
    ports, sent = ports.send(0, msg_new(XLAT_REQ, p0=vaddr, p1=idx),
                             when=want)
    state["issued"] = state["issued"] + sent.astype(jnp.int32)
    state["outstanding"] = state["outstanding"] + sent.astype(jnp.int32)
    return state, ports, TickResult.make(progress | sent)


def build_xlat(addr_list, max_vpn: int = 1 << 10, naive: bool = False):
    addrs = np.asarray(addr_list, np.int32)
    MAXA = len(addrs)
    b = SimBuilder()
    req = b.add_kind(ComponentKind(
        "core", requester_tick, 1, 1,
        {"addrs": jnp.asarray(addrs)[None, :],
         "n_addrs": jnp.full(1, MAXA, jnp.int32),
         "issued": jnp.zeros(1, jnp.int32),
         "outstanding": jnp.zeros(1, jnp.int32),
         "translated": jnp.zeros(1, jnp.int32),
         "last_paddr": jnp.zeros(1, jnp.int32)}, cap=2))
    l1 = b.add_kind(make_tlb_kind("l1tlb", 1, entries=4))
    l2 = b.add_kind(make_tlb_kind("l2tlb", 1, entries=16))
    mmu = b.add_kind(make_mmu_kind("mmu", 1, walk_latency=20.0,
                                   max_vpn=max_vpn))
    b.connect([req.port(0, 0), l1.port(0, 0)], latency=1.0)
    b.connect([l1.port(0, 1), l2.port(0, 0)], latency=1.0)
    b.connect([l2.port(0, 1), mmu.port(0, 0)], latency=1.0)
    sim = b.build(naive=naive)
    return sim, sim.init_state()


class PageFault(RuntimeError):
    pass


def run_translation_study(addr_list, max_vpn: int = 1 << 10,
                          domain: TracingDomain | None = None,
                          until: float = 10000.0):
    """Returns stats; raises :class:`PageFault` with an enhanced backtrace
    if the MMU hits an unmapped page (paper Fig. 6b)."""
    dom = domain or TracingDomain("xlat")
    sim, st = build_xlat(addr_list, max_vpn)
    with dom.task("simulation", "translation-study", "engine"):
        out = sim.run(st, until=until)
        faults = int(out.comp_state["mmu"]["faults"][0])
        if faults:
            bad = [a for a in addr_list if a // PAGE >= max_vpn]
            with dom.task("instruction", f"load 0x{bad[0]:x}", "Core0"):
                with dom.task("translation", f"vaddr 0x{bad[0]:x}",
                              "L1TLB[0]"):
                    with dom.task("translation", "miss -> L2", "L2TLB"):
                        with dom.task("page-walk", f"vpn {bad[0]//PAGE}",
                                      "MMU"):
                            raise PageFault("Page entry not found!")
    cs = out.comp_state
    return {
        "translated": int(cs["core"]["translated"][0]),
        "l1_hits": int(cs["l1tlb"]["hits"][0]),
        "l1_misses": int(cs["l1tlb"]["misses"][0]),
        "l2_hits": int(cs["l2tlb"]["hits"][0]),
        "l2_misses": int(cs["l2tlb"]["misses"][0]),
        "walks": int(cs["mmu"]["walks"][0]),
        "virtual_time": float(out.time),
    }
