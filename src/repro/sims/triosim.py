"""TrioSim: trace-driven multi-GPU DNN-training simulator (paper §5.2).

Purely event-driven on the Akita engine: each operator becomes ONE event
(compute ops fast-forward with ``next_time``; the paper: "condenses each
kernel/operator into a single event and fast-forwards without simulating
microarchitectural details").  Data movement uses a flow-based network
component (cf. Narses [17]) instead of cycle-level ports — the paper's
"alternative implementation of ports and connections".

Virtual time unit: 1 µs.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (ComponentKind, SimBuilder, TickResult, msg_new,
                        payload)
from .opgraph import COLL, COMPUTE, DONE, P2P_RECV, P2P_SEND, HW

REQ_COLL, REQ_P2P, DATA = 10, 11, 12


def gpu_tick(state, ports, t):
    state = dict(state)
    progress = jnp.asarray(False)
    msg, got, ports = ports.recv(0)
    tag_in = payload(msg, 1)
    state["got"] = jnp.where(got, state["got"].at[tag_in].set(1),
                             state["got"])
    progress = progress | got

    idx = state["idx"]
    op = state["ops"][idx]                     # [4]
    kind, size, tag, peer = op[0], op[1], op[2], op[3]
    infl = state["in_flight"] > 0

    # COMPUTE: schedule completion, then retire
    start_c = (kind == COMPUTE) & ~infl
    fin_c = (kind == COMPUTE) & infl & (t + 1e-3 >= state["busy_until"])
    state["busy_until"] = jnp.where(start_c, t + size.astype(jnp.float32),
                                    state["busy_until"])
    # COLL: request once, wait for completion tag
    start_k = (kind == COLL) & ~infl & ports.can_send(0)
    ports, sent_k = ports.send(
        0, msg_new(REQ_COLL, p0=size, p1=tag, p2=peer), when=start_k)
    fin_k = (kind == COLL) & infl & (state["got"][tag] > 0)
    # P2P
    can_s = (kind == P2P_SEND) & ports.can_send(0)
    ports, sent_p = ports.send(
        0, msg_new(REQ_P2P, p0=size, p1=tag, p2=peer), when=can_s)
    fin_r = (kind == P2P_RECV) & (state["got"][tag] > 0)
    # DONE
    fin_d = (kind == DONE) & (state["done"] == 0)
    state["done"] = jnp.where(fin_d, 1, state["done"])
    state["done_time"] = jnp.where(fin_d, t, state["done_time"])

    retire = fin_c | fin_k | sent_p | fin_r
    state["idx"] = jnp.clip(state["idx"] + retire.astype(jnp.int32), 0,
                            state["ops"].shape[0] - 1)
    state["in_flight"] = jnp.where(
        retire | fin_d, 0,
        jnp.where(start_c | sent_k, 1, state["in_flight"]))
    progress = progress | retire | start_c | sent_k | fin_d
    nxt = jnp.where(start_c | (fin_c & False), state["busy_until"], -1.0)
    nxt = jnp.where(retire, t + 1.0, nxt)      # look at the next op
    return state, ports, TickResult.make(progress, next_time=nxt)


def make_network_tick(n_gpus: int, hw: HW):
    inv_bw_us_per_kb = 1024.0 / hw.link_bw * 1e6

    def network_tick(state, ports, t):
        state = dict(state)
        progress = jnp.asarray(False)
        for p in range(n_gpus):
            msg, got, ports = ports.recv(p)
            kb = payload(msg, 0).astype(jnp.float32)
            tag = payload(msg, 1)
            grp = payload(msg, 2)
            is_coll = got & (msg[0] == REQ_COLL)
            is_p2p = got & (msg[0] == REQ_P2P)
            progress = progress | got
            # collective bookkeeping
            cnt = state["cnt"].at[tag].add(is_coll.astype(jnp.int32))
            state["cnt"] = jnp.where(got, cnt, state["cnt"])
            state["members"] = jnp.where(
                is_coll, state["members"].at[tag].add(
                    jnp.asarray(1 << p, jnp.int32)), state["members"])
            full = is_coll & (state["cnt"][tag] >= grp)
            dur = 2.0 * (grp - 1).astype(jnp.float32) / \
                jnp.maximum(grp, 1).astype(jnp.float32) * kb * \
                inv_bw_us_per_kb + hw.coll_alpha_us
            state["done_t"] = jnp.where(
                full, state["done_t"].at[tag].set(t + dur), state["done_t"])
            # p2p: serialize per destination channel (flow model)
            dstp = jnp.clip(grp, 0, n_gpus - 1)
            arr = jnp.maximum(t, state["chan_free"][dstp]) + \
                kb * inv_bw_us_per_kb + hw.coll_alpha_us
            state["chan_free"] = jnp.where(
                is_p2p, state["chan_free"].at[dstp].set(arr),
                state["chan_free"])
            state["done_t"] = jnp.where(
                is_p2p, state["done_t"].at[tag].set(arr), state["done_t"])
            state["members"] = jnp.where(
                is_p2p, state["members"].at[tag].set(
                    (1 << dstp).astype(jnp.int32)), state["members"])
        # deliver due completions, one per port per tick
        due_any = jnp.asarray(False)
        for p in range(n_gpus):
            bit = jnp.asarray(1 << p, jnp.int32)
            due = ((state["done_t"] <= t + 1e-3)
                   & ((state["members"] & bit) > 0)
                   & ((state["sent"] & bit) == 0))
            tagp = jnp.argmin(
                jnp.where(due, state["done_t"], jnp.inf)).astype(jnp.int32)
            have = jnp.any(due)
            ports, sent = ports.send(p, msg_new(DATA, p1=tagp), when=have)
            state["sent"] = jnp.where(
                sent, state["sent"].at[tagp].add(bit), state["sent"])
            progress = progress | sent
            due_any = due_any | have
        # sleep until the next completion still owed to someone
        owed = (state["done_t"] < jnp.inf) & \
            (state["sent"] != state["members"])
        nxt_t = jnp.min(jnp.where(owed, jnp.maximum(state["done_t"], t + 1.0),
                                  jnp.inf))
        nxt = jnp.where(jnp.isfinite(nxt_t), nxt_t, -1.0)
        return state, ports, TickResult.make(progress, next_time=nxt)

    return network_tick


def build_triosim(ops: np.ndarray, n_tags: int, hw: HW = HW()):
    """ops: [n_dev, MAX, 4] from opgraph.build_train_trace."""
    n_dev = ops.shape[0]
    assert n_dev <= 30, "bitmap member encoding limit"
    mt = max(n_tags + 1, 2)
    b = SimBuilder()
    gpus = b.add_kind(ComponentKind(
        "gpu", gpu_tick, n_dev, 1,
        {"ops": jnp.asarray(ops), "idx": jnp.zeros(n_dev, jnp.int32),
         "in_flight": jnp.zeros(n_dev, jnp.int32),
         "busy_until": jnp.zeros(n_dev, jnp.float32),
         "done": jnp.zeros(n_dev, jnp.int32),
         "done_time": jnp.zeros(n_dev, jnp.float32),
         "got": jnp.zeros((n_dev, mt), jnp.int32)}, cap=4))
    net = b.add_kind(ComponentKind(
        "net", make_network_tick(n_dev, hw), 1, n_dev,
        {"cnt": jnp.zeros((1, mt), jnp.int32),
         "members": jnp.zeros((1, mt), jnp.int32),
         "sent": jnp.zeros((1, mt), jnp.int32),
         "done_t": jnp.full((1, mt), jnp.inf, jnp.float32),
         "chan_free": jnp.zeros((1, n_dev), jnp.float32)}, cap=4))
    for g in range(n_dev):
        b.connect([gpus.port(g, 0), net.port(0, g)], latency=1.0)
    sim = b.build()
    return sim, sim.init_state()


def simulate_step(cfg, batch, seq, dp=1, tp=1, pp=1, micro=4, hw=HW(),
                  until=5e6):
    from .opgraph import build_train_trace
    ops, n_tags = build_train_trace(cfg, batch, seq, dp, tp, pp, micro, hw)
    sim, st = build_triosim(ops, n_tags, hw)
    out = sim.run(st, until=until, max_epochs=500_000)
    cs = out.comp_state["gpu"]
    done = bool(np.all(np.asarray(cs["done"]) == 1))
    step_us = float(np.max(np.asarray(cs["done_time"])))
    return {"done": done, "step_us": step_us,
            "epochs": int(out.stats.epochs)}
