"""GPU-like multi-core memory-system simulator — the Smart-Ticking
evaluation vehicle (paper §4 runs MGPUSim; we build the equivalent
cores + private L1 + shared-DRAM-over-crossbar system on the engine).

Workload patterns mirror the paper's benchmark behaviours:
  * ``compute``  — long think times, cores mostly busy (FIR/AES-like);
  * ``stream``   — back-to-back sequential misses, memory-bound (S2D-like);
  * ``pointer``  — serialized dependent misses (MLP=1);
  * ``idle_half``— half the cores have no work (ATAX's "limited
    parallelism", where Smart Ticking shines);
  * ``mixed``    — a blend.

Opcodes: 1=READ_REQ, 2=READ_RESP, 3=WRITE_REQ (fire-and-forget).
Payload: p0=address, p1=requester tag.

Sweepable model params (traced; see DSE.md): the ``core`` kind exposes
``think_scale`` (multiplier on per-core think times) and the ``l1`` kind
``extra_hit_rate`` (probability of a forced hit on top of the real tag
match — a stand-in for a bigger/smarter cache).  Both default to values
that reproduce the unparameterized model bit-for-bit (1.0 / 0.0); DRAM
service latency sweeps ride the crossbar connection latency and the
``dram`` kind's tick period.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ComponentKind, SimBuilder, TickResult, msg_new,
                        msg_reply, oh_set, opcode, payload)
from repro.core.pdes import ShardedSim, add_gateway

READ_REQ, READ_RESP, WRITE_REQ = 1, 2, 3

CORE_PARAMS = {"think_scale": jnp.float32(1.0)}
L1_PARAMS = {"extra_hit_rate": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
def core_tick(state, ports, t, params):
    """Issues reads with think-time compute phases; up to 1 outstanding."""
    progress = jnp.asarray(False)
    # accept response
    msg, got, ports = ports.recv(0)
    state = dict(state)
    state["outstanding"] = state["outstanding"] - got.astype(jnp.int32)
    progress = progress | got
    computing = t + 1e-3 < state["next_issue"]
    can_issue = ((state["remaining"] > 0) & (state["outstanding"] < 1)
                 & ~computing)
    # LCG address stream
    addr = (state["addr"] * 1103515245 + 12345) & 0x7FFFFFFF
    addr_use = jnp.where(state["seq"] > 0,
                         state["addr"] + 64, addr)  # sequential vs random
    ports, sent = ports.send(
        0, msg_new(READ_REQ, p0=addr_use, p1=state["tag"]), when=can_issue)
    si = sent.astype(jnp.int32)
    state["addr"] = jnp.where(sent, addr_use, state["addr"])
    state["remaining"] = state["remaining"] - si
    state["outstanding"] = state["outstanding"] + si
    think = state["think"].astype(jnp.float32) * params["think_scale"]
    state["next_issue"] = jnp.where(sent, t + think, state["next_issue"])
    progress = progress | sent
    # while computing, fast-forward to the next issue time (event-driven)
    nxt = jnp.where(computing & (state["remaining"] > 0)
                    & (state["outstanding"] < 1),
                    state["next_issue"], -1.0)
    return state, ports, TickResult.make(progress, next_time=nxt)


def l1_tick(state, ports, t, params):
    """Direct-mapped L1; 1 MSHR; port 0 = core side, port 1 = memory side."""
    state = dict(state)
    progress = jnp.asarray(False)
    n_sets = state["tags"].shape[0]

    # 1) fill response from memory
    rmsg, rgot, ports = ports.recv(1, when=ports.can_send(0))
    addr_r = payload(rmsg, 0)
    set_r = (addr_r // 64) % n_sets
    state["tags"] = oh_set(state["tags"], set_r, addr_r // 64, when=rgot)
    # reply to the core (port 0's paired peer), NOT to the fill's sender
    ports, _ = ports.send(0, msg_new(READ_RESP, p0=addr_r,
                                     p1=payload(rmsg, 1)), when=rgot)
    state["mshr_busy"] = jnp.where(rgot, 0, state["mshr_busy"])
    progress = progress | rgot

    # 2) new request from the core (only if we could respond / forward)
    can_hit_path = ports.can_send(0)
    can_miss_path = (state["mshr_busy"] == 0) & ports.can_send(1)
    msg, got = ports.peek(0)
    addr = payload(msg, 0)
    set_i = (addr // 64) % n_sets
    # forced probabilistic hit (address-hashed, deterministic): models a
    # larger/associative cache without simulating one; rate 0 == pure tags
    hmix = (addr * 1103515245 + 12345) & 0x7FFFFFFF
    forced = hmix.astype(jnp.float32) < \
        params["extra_hit_rate"] * jnp.float32(2147483648.0)
    hit = (state["tags"][set_i] == addr // 64) | forced
    accept = got & jnp.where(hit, can_hit_path, can_miss_path)
    _, _, ports = ports.recv(0, when=accept)
    ports, _ = ports.send(0, msg_reply(msg, READ_RESP, p0=addr,
                                       p1=payload(msg, 1)),
                          when=accept & hit)
    ports, fwd = ports.send(1, msg_new(READ_REQ, p0=addr, p1=payload(msg, 1)),
                            when=accept & ~hit)
    state["mshr_busy"] = jnp.where(fwd, 1, state["mshr_busy"])
    state["hits"] = state["hits"] + (accept & hit).astype(jnp.int32)
    state["misses"] = state["misses"] + fwd.astype(jnp.int32)
    progress = progress | accept
    return state, ports, TickResult.make(progress)


def dram_tick(state, ports, t):
    """One request per cycle; replies ride the connection latency."""
    state = dict(state)
    msg, got, ports = ports.recv(0, when=ports.can_send(0))
    op = opcode(msg)
    is_read = got & (op == READ_REQ)
    ports, _ = ports.send(0, msg_reply(msg, READ_RESP, p0=payload(msg, 0),
                                       p1=payload(msg, 1)), when=is_read)
    state["served"] = state["served"] + got.astype(jnp.int32)
    return state, ports, TickResult.make(got)


# ---------------------------------------------------------------------------
def _workload(pattern: str, n_cores: int, n_reqs: int, rng):
    think = np.zeros(n_cores, np.int32)
    seq = np.zeros(n_cores, np.int32)
    remaining = np.full(n_cores, n_reqs, np.int32)
    if pattern == "compute":
        think[:] = 24
    elif pattern == "stream":
        seq[:] = 1
        think[:] = 0
    elif pattern == "pointer":
        think[:] = 2
    elif pattern == "idle_half":
        remaining[n_cores // 2:] = 0
        think[:] = 4
    elif pattern == "mixed":
        think[:] = rng.integers(0, 16, n_cores)
        seq[:] = rng.integers(0, 2, n_cores)
    else:
        raise ValueError(pattern)
    return remaining, think, seq


def build_memsys(n_cores: int = 8, pattern: str = "mixed",
                 n_reqs: int = 64, dram_latency: float = 30.0,
                 naive: bool = False, seed: int = 0,
                 sample_period: float = 0.0, private_dram: bool = False,
                 super_epoch: int | None = None, donate: bool = True,
                 dram_period: float = 1.0):
    rng = np.random.default_rng(seed)
    remaining, think, seq = _workload(pattern, n_cores, n_reqs, rng)
    b = SimBuilder()
    cores = b.add_kind(ComponentKind(
        "core", core_tick, n_cores, 1,
        {"remaining": jnp.asarray(remaining),
         "outstanding": jnp.zeros(n_cores, jnp.int32),
         "addr": jnp.asarray(rng.integers(0, 1 << 20, n_cores), jnp.int32),
         "seq": jnp.asarray(seq),
         "think": jnp.asarray(think),
         "tag": jnp.arange(n_cores, dtype=jnp.int32),
         "next_issue": jnp.zeros(n_cores, jnp.float32)}, cap=2,
        params=CORE_PARAMS))
    n_sets = 64
    l1 = b.add_kind(ComponentKind(
        "l1", l1_tick, n_cores, 2,
        {"tags": jnp.full((n_cores, n_sets), -1, jnp.int32),
         "mshr_busy": jnp.zeros(n_cores, jnp.int32),
         "hits": jnp.zeros(n_cores, jnp.int32),
         "misses": jnp.zeros(n_cores, jnp.int32)}, cap=2,
        params=L1_PARAMS))
    n_dram = n_cores if private_dram else 1
    # dram_period is the service interval (one request per tick): the
    # static default of the sweepable ``period.dram`` axis
    dram = b.add_kind(ComponentKind(
        "dram", dram_tick, n_dram, 1,
        {"served": jnp.zeros(n_dram, jnp.int32)}, cap=4,
        period=dram_period))
    for i in range(n_cores):
        b.connect([cores.port(i, 0), l1.port(i, 0)], latency=1.0)
    if private_dram:
        # independent tiles (no shared-resource contention): the lane-
        # scaling measurement for transparent parallelism (Fig 10 analogue)
        for i in range(n_cores):
            b.connect([l1.port(i, 1), dram.port(i, 0)],
                      latency=dram_latency)
    else:
        # shared crossbar: every L1's memory port + the DRAM port on ONE
        # connection (Akita's multi-port round-robin crossbar)
        b.connect([l1.port(i, 1) for i in range(n_cores)]
                  + [dram.port(0, 0)], latency=dram_latency)
    sim = b.build(naive=naive, sample_period=sample_period,
                  super_epoch=super_epoch, donate=donate)
    st = sim.init_state()
    return sim, st


def finish_stats(sim, st):
    cs = st.comp_state
    return {
        "virtual_time": float(st.time),
        "epochs": int(st.stats.epochs),
        "ticks": int(st.stats.ticks),
        "delivered": int(st.stats.delivered),
        "reads_done": int(jnp.sum(cs["dram"]["served"])),
        "hits": int(jnp.sum(cs["l1"]["hits"])),
        "misses": int(jnp.sum(cs["l1"]["misses"])),
        "remaining": int(jnp.sum(cs["core"]["remaining"])),
        "outstanding": int(jnp.sum(cs["core"]["outstanding"])),
    }


# ---------------------------------------------------------------------------
# topology family: one padded build sweeping n_cores by activity mask
# ---------------------------------------------------------------------------
def build_family(shape=None, n_cores: int = 8, pattern: str = "mixed",
                 n_reqs: int = 64, dram_latency: float = 30.0, seed: int = 0,
                 super_epoch: int | None = None, donate: bool = True,
                 dram_period: float = 1.0, naive: bool = False):
    """The memsys topology *family* with up to ``n_cores`` cores.

    Built once at the family maximum (``pad_shape`` sizes the core/L1
    segments; the crossbar wires every potential L1 port plus the shared
    DRAM), it simulates any ``core`` count 1..n_cores via
    ``SimParams`` activity masks — one compile for the whole
    ``shape.core`` sweep axis (DSE.md "Topology families").

    Contractual detail that makes masked runs bit-identical to unpadded
    builds: active crossbar members occupy the leading member slots in
    instance order with the fixed DRAM port last, so round-robin
    arbitration sees the same relative slot order at every shape; and
    ``state_fn`` reseeds the workload RNG per shape, so active rows of
    the padded initial state equal ``build(n_cores=shape)`` exactly.

    Returns a :class:`repro.dse.TopologyFamily` with shape axis
    ``core`` (``run_sweep`` passes ``shape={"core": max}``).
    """
    from repro.dse.family import TopologyFamily

    if shape:
        # run_sweep passes the sweep's family maximum — size the padding
        # to it (an oversize family would tick masked rows for nothing)
        n_cores = int(shape.get("core", n_cores))
    n_max, n_sets = int(n_cores), 64
    b = SimBuilder()
    # kinds are declared as single-row templates; pad_shape sizes every
    # segment to the family maximum (zero rows — state_fn supplies the
    # per-shape workload, so the templates never reach a run)
    core = b.add_kind(ComponentKind(
        "core", core_tick, 1, 1,
        {"remaining": jnp.zeros(1, jnp.int32),
         "outstanding": jnp.zeros(1, jnp.int32),
         "addr": jnp.zeros(1, jnp.int32),
         "seq": jnp.zeros(1, jnp.int32),
         "think": jnp.zeros(1, jnp.int32),
         "tag": jnp.zeros(1, jnp.int32),
         "next_issue": jnp.zeros(1, jnp.float32)}, cap=2,
        params=CORE_PARAMS))
    l1 = b.add_kind(ComponentKind(
        "l1", l1_tick, 1, 2,
        {"tags": jnp.full((1, n_sets), -1, jnp.int32),
         "mshr_busy": jnp.zeros(1, jnp.int32),
         "hits": jnp.zeros(1, jnp.int32),
         "misses": jnp.zeros(1, jnp.int32)}, cap=2,
        params=L1_PARAMS))
    dram = b.add_kind(ComponentKind(
        "dram", dram_tick, 1, 1,
        {"served": jnp.zeros(1, jnp.int32)}, cap=4, period=dram_period))
    for i in range(n_max):
        b.connect([core.port(i, 0), l1.port(i, 0)], latency=1.0)
    b.connect([l1.port(i, 1) for i in range(n_max)] + [dram.port(0, 0)],
              latency=dram_latency)
    sim = b.build(naive=naive, super_epoch=super_epoch, donate=donate,
                  pad_shape={"core": n_max, "l1": n_max})
    dram_pid = sim.port_id("dram", 0, 0)
    sim.set_default_peers(
        {sim.port_id("l1", i, 1): dram_pid for i in range(n_max)})

    def state_fn(shape):
        n = int(shape["core"])
        # replay build()'s exact RNG sequence at this shape so active rows
        # of the padded state are bit-identical to an unpadded build
        rng = np.random.default_rng(seed)
        remaining, think, seq = _workload(pattern, n, n_reqs, rng)
        addr = rng.integers(0, 1 << 20, n).astype(np.int32)

        def pad(a):
            a = np.asarray(a)
            return np.concatenate(
                [a, np.zeros((n_max - n,) + a.shape[1:], a.dtype)])

        st = sim.init_state()
        cs = dict(st.comp_state)
        cs["core"] = {
            "remaining": pad(remaining),
            "outstanding": np.zeros(n_max, np.int32),
            "addr": pad(addr), "seq": pad(seq), "think": pad(think),
            "tag": np.arange(n_max, dtype=np.int32),
            "next_issue": np.zeros(n_max, np.float32)}
        cs["l1"] = {
            "tags": np.full((n_max, n_sets), -1, np.int32),
            "mshr_busy": np.zeros(n_max, np.int32),
            "hits": np.zeros(n_max, np.int32),
            "misses": np.zeros(n_max, np.int32)}
        cs["dram"] = {"served": np.zeros(1, np.int32)}
        return dataclasses.replace(
            st, comp_state=jax.tree.map(jnp.asarray, cs))

    return TopologyFamily(
        sim=sim, shape_max={"core": n_max},
        kind_counts=lambda s: {"core": s["core"], "l1": s["core"]},
        state_fn=state_fn)


# ---------------------------------------------------------------------------
# multi-member crossbar needs explicit dst: patch core/l1 states with gids
# ---------------------------------------------------------------------------
def _patch_dsts(sim, st, n_cores):
    dram_pid = sim.port_id("dram", 0, 0)
    # l1 memory-side sends go to the DRAM port; l1 replies use msg src. The
    # l1 tick uses msg_new for forwards (default peer = -1 on the crossbar),
    # so rewrite: default dst for the l1 mem port = dram port id.
    sim.set_default_peers(
        {sim.port_id("l1", i, 1): dram_pid for i in range(n_cores)})
    return sim, st


def build(n_cores=8, pattern="mixed", n_reqs=64, naive=False, seed=0,
          dram_latency=30.0, sample_period=0.0, private_dram=False,
          super_epoch=None, donate=True, dram_period=1.0):
    sim, st = build_memsys(n_cores, pattern, n_reqs, dram_latency, naive,
                           seed, sample_period, private_dram,
                           super_epoch=super_epoch, donate=donate,
                           dram_period=dram_period)
    if private_dram:
        return sim, st          # 1:1 links use default peers
    return _patch_dsts(sim, st, n_cores)


# ---------------------------------------------------------------------------
# sharded-PDES variant for the multi-pod dry-run (engine-as-workload)
# ---------------------------------------------------------------------------
def remote_writer_tick(state, ports, t):
    want = state["remaining"] > 0
    ports, sent = ports.send(0, msg_new(WRITE_REQ, p0=state["addr"]),
                             when=want)
    state = dict(state)
    state["remaining"] = state["remaining"] - sent.astype(jnp.int32)
    state["addr"] = state["addr"] + 64
    return state, ports, TickResult.make(sent)


def build_sharded_memsys(mesh=None, n_shards: int = 1,
                         tiles_per_shard: int = 4, n_reqs: int = 32,
                         lookahead: float = 8.0):
    """Each shard: a memsys tile + a writer streaming to the right-neighbor
    shard's DRAM through the PDES gateway (ring topology, 1 peer)."""

    # NB: the gateway ingress cannot share the DRAM's crossbar port (Akita:
    # one connection per port), so the DRAM gets a second port for remote
    # traffic.
    def build_fn():
        n_cores = tiles_per_shard
        b = SimBuilder()
        rng = np.random.default_rng(0)
        remaining, think, seq = _workload("mixed", n_cores, n_reqs, rng)
        cores = b.add_kind(ComponentKind(
            "core", core_tick, n_cores, 1,
            {"remaining": jnp.asarray(remaining),
             "outstanding": jnp.zeros(n_cores, jnp.int32),
             "addr": jnp.asarray(rng.integers(0, 1 << 20, n_cores),
                                 jnp.int32),
             "seq": jnp.asarray(seq), "think": jnp.asarray(think),
             "tag": jnp.arange(n_cores, dtype=jnp.int32),
             "next_issue": jnp.zeros(n_cores, jnp.float32)}, cap=2,
            params=CORE_PARAMS))
        l1 = b.add_kind(ComponentKind(
            "l1", l1_tick, n_cores, 2,
            {"tags": jnp.full((n_cores, 64), -1, jnp.int32),
             "mshr_busy": jnp.zeros(n_cores, jnp.int32),
             "hits": jnp.zeros(n_cores, jnp.int32),
             "misses": jnp.zeros(n_cores, jnp.int32)}, cap=2,
            params=L1_PARAMS))
        dram = b.add_kind(ComponentKind(
            "dram", dram_tick, 1, 2, {"served": jnp.zeros(1, jnp.int32)},
            cap=8))
        writer = b.add_kind(ComponentKind(
            "writer", remote_writer_tick, 1, 1,
            {"remaining": jnp.full(1, n_reqs, jnp.int32),
             "addr": jnp.zeros(1, jnp.int32)}, cap=2))
        gw = add_gateway(b, n_peers=1, chan_per_peer=1, cap=8)
        for i in range(n_cores):
            b.connect([cores.port(i, 0), l1.port(i, 0)], latency=1.0)
        b.connect([l1.port(i, 1) for i in range(n_cores)]
                  + [dram.port(0, 0)], latency=16.0)
        b.connect([writer.port(0, 0), gw.port(0, 0)], latency=1.0)
        b.connect([gw.port(0, 1), dram.port(0, 1)], latency=1.0)
        return b, gw

    ss = ShardedSim(build_fn, n_shards=n_shards, n_peers=1,
                    chan_per_peer=1, mesh=mesh, lookahead=lookahead,
                    mailbox=8)
    # the l1 crossbar needs explicit DRAM addressing (multi-member conn)
    dram_pid = ss.sim.port_id("dram", 0, 0)
    ss.sim.set_default_peers(
        {ss.sim.port_id("l1", i, 1): dram_pid
         for i in range(tiles_per_shard)})
    return ss
