"""Serving steps: prefill (context -> cache) and decode (one token against
the cache), with cache shardings for the production meshes.

Cache sharding rules (see DESIGN.md §5):
* batch over (pod, data) when divisible;
* GQA KV heads over 'model' when divisible, else head_dim over 'model'
  (deepseek-67b/grok/internvl: kv=8 < tp=16 -> shard the 128-wide head_dim);
* MLA latent: kv_lora (512) over 'model';
* long_500k (batch=1): sequence dimension over 'data' (sequence parallelism
  for the KV cache; attention contracts over the sharded S with a psum);
* SSM states: batch-sharded only (O(1) size).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.layers import abstract_params, make_pspecs
from repro.parallel.sharding import batch_pspec, make_rules_for_mesh
from repro.train.step import abstract_batch


def cache_pspecs(cfg, mesh, B: int, S: int, unrolled: bool):
    tp = mesh.shape["model"]
    bp = batch_pspec(mesh, B)              # P over batch dim (maybe empty)
    b0 = bp[0] if len(bp) else None
    seq = "data" if (b0 is None and S % mesh.shape["data"] == 0) else None
    kv_ax = "model" if (cfg.n_kv_heads and cfg.n_kv_heads % tp == 0) else None
    hd_ax = "model" if (kv_ax is None and cfg.head_dim
                        and cfg.head_dim % tp == 0) else None

    def attn_specs(with_layer):
        l = (None,) if with_layer else ()
        return {
            "k": P(*l, b0, seq, kv_ax, hd_ax),
            "v": P(*l, b0, seq, kv_ax, hd_ax),
        }

    def mla_specs(with_layer):
        l = (None,) if with_layer else ()
        lat = "model" if cfg.kv_lora % tp == 0 else None
        return {"ckv": P(*l, b0, seq, lat), "kr": P(*l, b0, seq, None)}

    def ssm_specs(with_layer):
        l = (None,) if with_layer else ()
        return {"conv": P(*l, b0, None, None),
                "ssm": P(*l, b0, None, None, None)}

    if unrolled:
        per_layer = []
        for w in cfg.layer_windows():
            lc = {}
            if cfg.has_attn:
                s_layer = "data" if (b0 is None and
                                     min(w, S) % mesh.shape["data"] == 0
                                     and not (0 < w < S)) else None
                lc.update(attn_specs(False))
                lc["pos"] = P(b0, None)
            if cfg.has_ssm:
                lc.update(ssm_specs(False))
            per_layer.append(lc)
        return {"layers": per_layer}
    c = {}
    if cfg.has_attn:
        c.update(mla_specs(True) if cfg.use_mla else attn_specs(True))
    if cfg.has_ssm:
        c.update(ssm_specs(True))
    return c


def make_decode_step(cfg, unrolled: bool):
    def decode_step(params, cache, tokens, positions):
        if unrolled:
            logits, cache = tfm.decode_unrolled(params, cfg, tokens, cache,
                                                positions)
        else:
            logits, cache, _ = tfm.forward(
                params, cfg, {"tokens": tokens}, mode="decode", cache=cache,
                positions=positions, cache_len=positions + 1)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, cache

    return decode_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        logits, cache, _ = tfm.forward(params, cfg, batch, mode="prefill")
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, cache

    return prefill_step


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def assemble_decode(cfg, mesh, shape):
    """Jitted decode step + abstract (params, cache, tokens, positions)."""
    B, S = shape.global_batch, shape.seq_len
    unrolled = tfm.needs_unrolled_decode(cfg, S)
    rules = make_rules_for_mesh(cfg, mesh)
    specs = tfm.model_specs(cfg)
    p_pspecs = make_pspecs(specs, rules)
    params = abstract_params(specs)
    cache_fn = tfm.init_cache_unrolled if unrolled else tfm.init_cache
    cache = jax.eval_shape(partial(cache_fn, cfg, B, S))
    c_pspecs = cache_pspecs(cfg, mesh, B, S, unrolled)
    bp = batch_pspec(mesh, B)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tp_spec = P(bp[0] if len(bp) else None, None)

    step = make_decode_step(cfg, unrolled)
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, p_pspecs), _ns(mesh, c_pspecs),
                      NamedSharding(mesh, tp_spec),
                      NamedSharding(mesh, tp_spec)),
        out_shardings=(NamedSharding(mesh, P(bp[0] if len(bp) else None)),
                       _ns(mesh, c_pspecs)),
        donate_argnums=(1,))
    return jitted, (params, cache, tok, pos)


def assemble_prefill(cfg, mesh, shape):
    rules = make_rules_for_mesh(cfg, mesh)
    specs = tfm.model_specs(cfg)
    p_pspecs = make_pspecs(specs, rules)
    params = abstract_params(specs)
    batch = abstract_batch(cfg, shape)
    from repro.train.step import batch_pspecs as bspecs_fn
    b_pspecs = bspecs_fn(cfg, mesh, shape)
    B, S = shape.global_batch, shape.seq_len
    bp = batch_pspec(mesh, B)
    c_pspecs = cache_pspecs(cfg, mesh, B, S, unrolled=False)

    step = make_prefill_step(cfg)
    out_shardings = (NamedSharding(mesh, P(bp[0] if len(bp) else None)),
                     _ns(mesh, c_pspecs))
    jitted = jax.jit(step,
                     in_shardings=(_ns(mesh, p_pspecs), _ns(mesh, b_pspecs)),
                     out_shardings=out_shardings)
    return jitted, (params, batch)
