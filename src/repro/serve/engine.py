"""Continuous-batching serving engine.

Fixed decode slots share one stacked KV cache; requests are admitted into
free slots (prefill writes the slot's cache region), and one fused decode
step advances every active slot.  The loop follows Smart-Ticking semantics
(paper §3.2, applied to serving): when no slot is active it *sleeps* —
no decode steps are issued — and request arrival wakes it; idle slots ride
along masked (the vectorized engine's lane-masking analogy, DESIGN.md §2).

Every request is a traced task (submit -> prefill -> decode* -> finish), so
AkitaRTM-style monitoring and Daisen export work on the serving loop too.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tracing import TracingDomain
from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    slot: int = -1
    task: object = None
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, max_batch: int = 4, max_len: int = 256,
                 eos_id: int | None = None,
                 domain: TracingDomain | None = None):
        assert not tfm.needs_unrolled_decode(cfg, max_len), \
            "slot engine uses the scanned decode path"
        self.cfg, self.params = cfg, params
        self.B, self.S = max_batch, max_len
        self.eos = eos_id
        self.dom = domain or TracingDomain("serve")
        self.cache = tfm.init_cache(cfg, max_batch, max_len)
        self.pos = np.zeros(max_batch, np.int32)      # next write position
        self.active: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self.last_tok = np.zeros(max_batch, np.int32)
        self._rid = itertools.count()
        self._decode = jax.jit(self._decode_fn)

    # ------------------------------------------------------------------
    def submit(self, prompt_tokens, max_new: int = 32) -> int:
        r = Request(next(self._rid), np.asarray(prompt_tokens, np.int32),
                    max_new)
        r.task = self.dom.start_task("request", "serve", "engine",
                                     rid=r.rid, prompt_len=len(r.prompt))
        self.queue.append(r)
        return r.rid

    def _admit(self):
        for slot in range(self.B):
            if self.active[slot] is not None or not self.queue:
                continue
            r = self.queue.pop(0)
            r.slot = slot
            with self.dom.task("prefill", f"len{len(r.prompt)}",
                               f"slot{slot}"):
                toks = jnp.asarray(r.prompt)[None, :]
                logits, pcache, _ = tfm.forward(self.params, self.cfg,
                                                {"tokens": toks},
                                                mode="prefill")
                S0 = len(r.prompt)
                for k, v in pcache.items():
                    dst = self.cache[k]
                    if k in ("k", "v", "ckv", "kr"):
                        self.cache[k] = dst.at[:, slot, :S0].set(
                            v[:, 0].astype(dst.dtype))
                    else:
                        self.cache[k] = dst.at[:, slot].set(
                            v[:, 0].astype(dst.dtype))
                nxt = int(jnp.argmax(logits[0, -1]))
            self.active[slot] = r
            self.pos[slot] = S0
            self.last_tok[slot] = nxt
            r.out.append(nxt)

    # ------------------------------------------------------------------
    def _decode_fn(self, params, cache, tokens, positions):
        logits, cache, _ = tfm.forward(
            params, self.cfg, {"tokens": tokens}, mode="decode", cache=cache,
            positions=positions, cache_len=positions + 1)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    def step(self) -> list[Request]:
        """Admit + one fused decode step.  Smart-Ticking: returns without
        touching the device when every slot is idle (progress=False)."""
        self._admit()
        if all(r is None for r in self.active):
            return []
        with self.dom.task("decode", "step", "engine",
                           active=sum(r is not None for r in self.active)):
            toks = jnp.asarray(self.last_tok)[:, None]
            pos = jnp.asarray(self.pos)[:, None]
            nxt, self.cache = self._decode(self.params, self.cache, toks,
                                           pos)
            nxt = np.asarray(nxt)
        finished = []
        for slot, r in enumerate(self.active):
            if r is None:
                continue
            self.pos[slot] += 1
            tok = int(nxt[slot])
            r.out.append(tok)
            self.last_tok[slot] = tok
            hit_eos = self.eos is not None and tok == self.eos
            if len(r.out) >= r.max_new or hit_eos or \
                    self.pos[slot] >= self.S - 1:
                r.done = True
                self.dom.tag_task("eos" if hit_eos else "length",
                                  t=r.task)
                self.dom.end_task(r.task)
                finished.append(r)
                self.active[slot] = None
        return finished

    def run_until_idle(self, max_steps: int = 10_000):
        done = []
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            done += self.step()
        return done
