"""AdamW with optional 8-bit (error-feedback-free, blockwise-scaled) moment
states — the optimizer-memory half of the distributed-optimization story:
m/v in int8 cut optimizer bytes 8x, which is what lets grok-1-314b train on
a single 256-chip pod (see EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def _q8(x):
    """Blockwise int8 quantization along the flattened last axis."""
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def adamw_init(params, *, moments_dtype: str = "float32"):
    def zero(p):
        if moments_dtype == "int8":
            q, s = _q8(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zero, params),
        "v": jax.tree.map(zero, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, moments_dtype: str = "float32"):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    int8 = moments_dtype == "int8"

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        mf = _dq8(m["q"], m["s"], g.shape) if int8 else m
        vf = _dq8(v["q"], v["s"], g.shape) if int8 else v
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        mh = mf / (1 - b1 ** cf)
        vh = vf / (1 - b2 ** cf)
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        if int8:
            qm, sm = _q8(mf)
            qv, sv = _q8(vf)
            return new_p, {"q": qm, "s": sm}, {"q": qv, "s": sv}
        return new_p, mf, vf

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    leaves, treedef = jax.tree.flatten(
        out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_m = treedef.unflatten([l[1] for l in leaves])
    new_v = treedef.unflatten([l[2] for l in leaves])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor
                                   ).astype(g.dtype), grads), norm
