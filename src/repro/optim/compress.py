"""Gradient compression: int8 error-feedback all-reduce (shard_map manual
collective) — the communication half of the distributed-optimization story.

In SPMD jit, the DP gradient all-reduce is implicit; to compress it we drop
to ``shard_map`` over the data axes, quantize each shard's gradient to int8
(per-tensor scale), ``psum`` the int8 payload (accumulated in int32) and
dequantize — 4x fewer bytes on the wire than f32 / 2x vs bf16.  The
quantization error is fed back into the next step's gradient (error
feedback), which keeps convergence (validated in tests/test_optim.py on a
toy problem).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def quant_int8(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def int8_allreduce_grads(grads, err, mesh, axes=("data",)):
    """All-reduce-mean per-shard grads in int8 with error feedback.

    grads: per-shard gradient pytree (inside shard_map or via api below);
    err: error-feedback state (same tree).  Returns (reduced, new_err).
    """
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quant_int8(gf)
        g_hat = dequant_int8(q, s)
        new_e = gf - g_hat
        # Wire format: int8 payload psum'd in int32 (4x fewer bytes than f32)
        # + one f32 scale per tensor; value = sum_i q_i * s_i / n.  Per-shard
        # scales differ, so the scale rides along and each shard's payload is
        # rescaled to the max scale before the integer reduction.
        s_max = jax.lax.pmax(s, axes)
        q_resc = jnp.round(q.astype(jnp.float32) * (s / s_max)
                           ).astype(jnp.int32)
        total = jax.lax.psum(q_resc, axes)
        red = total.astype(jnp.float32) * s_max / n
        return red.astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])
