from .adamw import adamw_init, adamw_update, clip_by_global_norm  # noqa
from .compress import (dequant_int8, int8_allreduce_grads,  # noqa
                       quant_int8)
