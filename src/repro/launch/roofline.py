"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
  memory     = HLO_bytes_per_chip / HBM_BW
  collective = ring-model bytes on the wire per chip / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD, per-chip
program).  Collective bytes are parsed from ``compiled.as_text()``: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute's
result type × ring factor, with ``replica_groups`` giving the group size g
and while-loop trip counts (layer scans, microbatch loops) multiplying ops
that live inside loop bodies.

Hardware constants (TPU v5e-class, from the task sheet): 197 TFLOP/s bf16,
819 GB/s HBM, 2×50 GB/s effective bidirectional ICI ring bandwidth per chip.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 100e9          # 2 links/ring direction x 50 GB/s

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]\w*?)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    total_bytes: float                 # per-chip wire bytes (ring model)
    count: int


@dataclasses.dataclass
class HloStats:
    """Loop-aware totals parsed from post-SPMD HLO text."""
    dot_flops: float                   # 2*M*N*K per dot × trip multipliers
    result_bytes: float                # Σ op result bytes × multipliers
    collectives: CollectiveStats


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith((" ", "\t")):
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


_DEF_RE = re.compile(
    r"^%?([\w\.\-]+)\s*=\s*([a-z]\w*\[[\d,]*\])\S*\s+([\w\-]+)\(")
_DOT_RE = re.compile(
    r"^%?[\w\.\-]+\s*=\s*([a-z]\w*\[[\d,]*\])\S*\s+dot\("
    r"%?([\w\.\-]+), %?([\w\.\-]+)\), (.*)")
# ops that move no bytes (aliased/metadata-only in the optimized program);
# dynamic-update-slice is in-place on loop carries: only the update counts.
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "bitcast",
             "reshape", "constant", "iota", "after-all"}


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _comp_multipliers(comps) -> dict:
    """Propagate while-loop trip counts to loop-body computations."""
    whiles = []
    for cname, lines in comps.items():
        for ln in lines:
            m = re.search(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*"
                          r"body=%?([\w\.\-]+)", ln)
            if m:
                whiles.append((cname, m.group(1), m.group(2)))

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = {}
        for ln in lines:
            m = re.match(r"^%?([\w\.\-]+)\s*=.*?constant\((\d+)\)", ln)
            if m:
                consts[m.group(1)] = int(m.group(2))
        # the loop bound is the constant operand of the compare op
        bounds = []
        for ln in lines:
            m = re.search(r"compare\(%?([\w\.\-]+), %?([\w\.\-]+)\)", ln)
            if m:
                for nm in m.groups():
                    if nm in consts:
                        bounds.append(consts[nm])
        if bounds:
            return max(bounds)
        return max(consts.values()) if consts else 1

    mult = {c: 1 for c in comps}
    for _ in range(20):
        changed = False
        for parent, cond, body in whiles:
            m = mult.get(parent, 1) * max(trip_count(cond), 1)
            if mult.get(body, 1) != m:
                mult[body] = m
                changed = True
        if not changed:
            break
    return mult


def parse_hlo(hlo_text: str, n_devices: int) -> HloStats:
    """Loop-aware dot FLOPs, result-buffer bytes, and collective bytes."""
    comps = _split_computations(hlo_text)
    mult = _comp_multipliers(comps)

    # global symbol table: array-typed defs (for dot/DUS operand shapes)
    sym: dict[str, list[int]] = {}
    sym_bytes: dict[str, int] = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                sym[m.group(1)] = _dims(m.group(2))
                sym_bytes[m.group(1)] = _type_bytes(m.group(2))

    dot_flops = 0.0
    result_bytes = 0.0
    for cname, lines in comps.items():
        k = mult.get(cname, 1)
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                opname = m.group(3)
                if opname == "dynamic-update-slice":
                    dm2 = re.search(
                        r"dynamic-update-slice\(%?[\w\.\-]+, %?([\w\.\-]+)",
                        ln)
                    if dm2:
                        result_bytes += sym_bytes.get(dm2.group(1), 0) * k
                elif opname not in _FREE_OPS:
                    result_bytes += _type_bytes(m.group(2)) * k
            dm = _DOT_RE.match(ln)
            if dm:
                out_t, lhs, rhs, rest = dm.groups()
                out_n = 1
                for d in _dims(out_t):
                    out_n *= d
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                K = 1
                if cm and lhs in sym:
                    lshape = sym[lhs]
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lshape):
                            K *= lshape[int(ci)]
                dot_flops += 2.0 * out_n * K * k

    coll = _parse_collectives_split(comps, mult, n_devices)
    return HloStats(dot_flops, result_bytes, coll)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    return _parse_collectives_split(comps, _comp_multipliers(comps),
                                    n_devices)


def _parse_collectives_split(comps, mult, n_devices) -> CollectiveStats:
    by_op: dict[str, float] = {}
    count = 0
    for cname, lines in comps.items():
        k = mult.get(cname, 1)
        for ln in lines:
            m = re.match(r"%?[\w\.\-]+\s*=\s*(.+?)\s+([a-z\-]+)(?:-start)?\(",
                         ln)
            if not m:
                continue
            type_str, op = m.group(1), m.group(2)
            if op not in _COLL:
                continue
            g = _group_size(ln, n_devices)
            if g <= 1:
                continue
            b = _type_bytes(type_str)
            ring = (g - 1) / g
            if op == "all-reduce":
                wire = 2 * b * ring
            elif op == "reduce-scatter":
                wire = b * (g - 1)          # result is the 1/g piece
            elif op == "all-gather":
                wire = b * ring
            elif op == "all-to-all":
                wire = b * ring
            else:                           # collective-permute
                wire = b
            by_op[op] = by_op.get(op, 0.0) + wire * k
            count += k
    return CollectiveStats(by_op, sum(by_op.values()), count)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D forward (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch      # decode: 1 token per sequence


def analyze(compiled, cfg, shape, n_devices: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    # cost_analysis counts while-loop (layer-scan) bodies ONCE — parse the
    # HLO with trip-count multipliers instead; keep cost_analysis as a floor.
    hlo = parse_hlo(compiled.as_text(), n_devices)
    flops = max(float(cost.get("flops", 0.0)), hlo.dot_flops)
    # memory traffic proxy: every op result written once + read once at the
    # fusion granularity of the optimized HLO (see DESIGN.md §6).
    hbm_bytes = max(float(cost.get("bytes accessed", 0.0)),
                    2.0 * hlo.result_bytes)
    coll = hlo.collectives
    mem = compiled.memory_analysis()
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll.total_bytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    bound = max(max(terms.values()), 1e-12)
    if shape.kind == "decode" and mem is not None:
        # decode is memory-bound by construction: the ideal step reads every
        # argument byte (params + caches) exactly once.
        ideal = mem.argument_size_in_bytes / HBM_BW
    else:
        ideal = (mf / n_devices) / PEAK_FLOPS
    return {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": hbm_bytes,
        "collective_bytes_per_chip": coll.total_bytes,
        "collective_by_op": coll.bytes_by_op,
        "collective_op_count": coll.count,
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_devices,
        "useful_flops_ratio": (mf / n_devices) / flops if flops else 0.0,
        "step_lower_bound_s": max(terms.values()),
        "ideal_step_s": ideal,
        "roofline_fraction": min(1.0, ideal / bound),
        "memory_per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "total_bytes": (mem.argument_size_in_bytes
                            + mem.temp_size_in_bytes),
        } if mem is not None else None,
    }
