"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 50 --ckpt runs/train_demo

Any assigned architecture id works (--smoke selects the reduced config that
actually runs on this CPU container; the full configs are exercised by the
dry-run).  The loop is fault-tolerant: checkpoints periodically, drains on
SIGTERM, resumes automatically, and traces every step into the Akita task
DB (--trace-db) for Daisen export.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--moments-dtype", default="float32",
                    choices=["float32", "int8"])
    ap.add_argument("--ckpt", default="runs/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--trace-db", default=None)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.core.tracing import TracingDomain
    from repro.data import DataPipeline
    from repro.train.loop import LoopConfig, train
    from repro.train.step import TrainHParams

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")
    data = DataPipeline(cfg, batch=args.batch, seq=args.seq)
    dom = TracingDomain("train")
    db = None
    if args.trace_db:
        from repro.core.tracers import DBTracer
        db = dom.attach(DBTracer(args.trace_db))
    _, _, hist = train(
        cfg, data,
        LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                   ckpt_dir=args.ckpt, log_every=10),
        TrainHParams(lr=args.lr, micro_batches=args.micro_batches,
                     moments_dtype=args.moments_dtype, donate=False),
        domain=dom, resume=not args.no_resume)
    if db:
        db.close()
    print(f"done: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({len(hist)} steps this process)")


if __name__ == "__main__":
    main()
