"""Serving launcher: continuous batching over a checkpoint (or fresh
random weights for a topology demo).

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --ckpt runs/train_demo --prompts "hello world" "the quick brown"
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--prompts", nargs="+", default=["hello world"])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.data import ByteTokenizer
    from repro.models import transformer as tfm
    from repro.models.layers import init_params
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(0))
    if args.ckpt:
        from repro.ckpt import restore_checkpoint
        from repro.optim import adamw_init
        state, manifest = restore_checkpoint(
            args.ckpt, {"p": params, "o": adamw_init(params)})
        params = state["p"]
        print(f"restored step {manifest['step']} from {args.ckpt}")

    tok = ByteTokenizer()
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len)
    for p in args.prompts:
        eng.submit(tok.encode(p) % cfg.vocab, max_new=args.max_new)
    done = eng.run_until_idle()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"[{r.rid}] {tok.decode(list(r.prompt))!r} -> "
              f"{tok.decode(r.out)!r}")


if __name__ == "__main__":
    main()
