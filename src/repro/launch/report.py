"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from runs/dryrun."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(rows, mesh):
    hdr = ("| arch | shape | status | compile s | args GiB/chip | "
           "temp GiB/chip | fits 16GB |\n|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP: "
                       f"{r['reason'][:60]}... | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | |")
            continue
        m = r["memory_per_device"]
        tot = (m["argument_bytes"] + m["temp_bytes"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['lower_compile_s']} | "
            f"{fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])}"
            f" | {'YES' if tot <= 16 else f'NO ({tot:.0f} GiB)'} |")
    return "\n".join(out)


def roofline_table(rows, mesh="16x16"):
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPs/HLO | roofline frac | one-line fix |\n"
           "|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    fixes = {
        "memory": "fuse attention temporaries (Pallas FA) / cast "
                  "collectives+softmax to bf16",
        "collective": "sequence-parallel RS+AG instead of AR; overlap "
                      "via async collectives",
        "compute": "already MXU-bound; raise per-chip batch or reduce remat",
    }
    for r in rows:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {fixes[r['dominant']]} |")
    return "\n".join(out)


def pick_hillclimb(rows):
    ok = [r for r in rows if r["status"] == "ok" and r["mesh"] == "16x16"]
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["collective_s"] /
               max(r["step_lower_bound_s"], 1e-12))
    return worst, coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.out)
    print("### Dry-run —", args.mesh)
    print(dryrun_table(rows, args.mesh))
    print("\n### Roofline —", args.mesh)
    print(roofline_table(rows, args.mesh))
    w, c = pick_hillclimb(rows)
    print(f"\nworst roofline: {w['arch']}×{w['shape']} "
          f"({w['roofline_fraction']:.3f}); most collective-bound: "
          f"{c['arch']}×{c['shape']} ({c['collective_s']:.3f}s)")


if __name__ == "__main__":
    main()
