"""Production meshes.

Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod:  2x16x16 = 512 chips ("pod", "data", "model") — "pod" is the
outer data-parallel/FSDP axis (DCN-ish in real deployments); nothing below
binds to these sizes, so 1000+-node meshes are a parameter change here.

NOTE: functions, not module constants — importing this module must never
touch jax device state (device count is locked at first use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Tiny mesh for the 8-fake-device subprocess tests."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sim_mesh(n: int | None = None):
    """1-D mesh over all devices for the sharded-PDES engine workload."""
    n = n or len(jax.devices())
    return jax.make_mesh((n,), ("sim",))
