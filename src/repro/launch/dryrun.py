import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input shape) cell on the production
meshes — 16×16 single-pod and 2×16×16 multi-pod — and records
``memory_analysis`` / ``cost_analysis`` / collective-bytes for §Dry-run and
§Roofline.  ShapeDtypeStruct stand-ins everywhere: nothing is allocated.

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out runs/dryrun]
  python -m repro.launch.dryrun --sim          # the engine as a workload
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hillclimb: dict | None = None) -> dict:
    import jax

    from repro.configs import SHAPES, applicable, get_config
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.serve.step import assemble_decode, assemble_prefill
    from repro.train.step import TrainHParams, assemble_train

    cfg = get_config(arch, **(hillclimb.get("cfg", {}) if hillclimb else {}))
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "params_total": cfg.param_count(),
           "params_active": cfg.active_param_count(),
           "override": hillclimb}
    ok, why = applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    from repro.parallel.sharding import (activation_sharding,
                                         make_rules_for_mesh)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    if shape.kind == "train":
        hp = TrainHParams(**(hillclimb.get("hp", {}) if hillclimb else {}))
        jitted, args = assemble_train(cfg, mesh, shape, hp)
    elif shape.kind == "prefill":
        jitted, args = assemble_prefill(cfg, mesh, shape)
    else:
        jitted, args = assemble_decode(cfg, mesh, shape)
    with mesh, activation_sharding(mesh, make_rules_for_mesh(cfg, mesh)):
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    rec["lower_compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {rec['mesh']}] memory_analysis:")
    print(f"  args={mem.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
          f"out={mem.output_size_in_bytes/2**30:.2f}GiB")
    cost = compiled.cost_analysis()
    print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")
    rec.update(status="ok", **roofline.analyze(compiled, cfg, shape, n_dev))
    return rec


def run_sim_cell(multi_pod: bool) -> dict:
    """The paper's engine itself as a multi-pod workload: the sharded-PDES
    memsys simulation lowered on the production mesh ('sim' = all chips)."""
    import jax

    from repro.launch import roofline
    from repro.sims.memsys import build_sharded_memsys

    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("sim",))
    t0 = time.time()
    ss = build_sharded_memsys(mesh=mesh, n_shards=n, tiles_per_shard=4)
    lowered = ss.lower(until=4096.0)
    compiled = lowered.compile()
    rec = {"arch": "akita-memsys-pdes", "shape": f"{n}shards",
           "mesh": f"{n}", "status": "ok",
           "lower_compile_s": round(time.time() - t0, 1)}
    mem = compiled.memory_analysis()
    print(f"[akita-memsys-pdes x {n} shards] "
          f"args={mem.argument_size_in_bytes/2**20:.1f}MiB "
          f"temp={mem.temp_size_in_bytes/2**20:.1f}MiB")
    coll = roofline.parse_collectives(compiled.as_text(), n)
    rec["collective_bytes_per_chip"] = coll.total_bytes
    rec["collective_by_op"] = coll.bytes_by_op
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sim", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--override", default=None,
                    help='hillclimb JSON, e.g. \'{"hp":{"micro_batches":8},'
                         '"cfg":{"remat":"none"},"tag":"mb8"}\'')
    args = ap.parse_args()
    override = json.loads(args.override) if args.override else None

    from repro.configs import ARCH_IDS, SHAPES

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.sim:
        for mp in meshes:
            cells.append(("__sim__", "", mp))
    elif args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    results = []
    for a, s, mp in cells:
        tag = f"{a}_{s}_{'mp' if mp else 'sp'}".replace("__sim___", "sim_")
        if override and override.get("tag"):
            tag += "_" + override["tag"]
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"== {tag}: cached, skipping")
            results.append(json.load(open(path)))
            continue
        print(f"== {tag}")
        try:
            rec = run_sim_cell(mp) if a == "__sim__" else \
                run_cell(a, s, mp, hillclimb=override)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "mesh": "mp" if mp else "sp",
                   "status": "error", "error": repr(e)}
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1, default=str)
        results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDONE: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
