from .sharding import (batch_pspec, data_axes, make_rules,  # noqa: F401
                       make_rules_for_mesh)
