"""Logical-axis → mesh-axis rules (DP/FSDP/TP/EP/SP).

Parameters carry *logical* axes ('fsdp', 'tensor', 'tensor_q', 'tensor_kv',
'tensor_vocab', 'expert', 'expert_ff'); this module resolves them for a
concrete (config, mesh) pair with divisibility-aware fallbacks:

* ``fsdp``      -> ('pod','data') — ZeRO-3 parameter/optimizer sharding
* ``tensor``    -> 'model' (Megatron TP on d_ff / vocab-padded dims)
* ``tensor_q``  -> 'model' if n_heads % tp == 0 else None (phi3: 40 heads)
* ``tensor_kv`` -> 'model' if n_kv_heads % tp == 0 else None (GQA kv<tp:
                   replicate KV projections; decode caches shard head_dim)
* ``expert``    -> 'model' when E % tp == 0 (EP: deepseek-v2 160/16),
                   else None with ``expert_ff`` -> 'model' (grok-1: 8 experts
                   tensor-sharded on their 32768-wide FFN)
* SSM params    -> fsdp-only (head counts of the assigned SSM/hybrid archs
                   don't divide tp; documented in DESIGN.md)
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# --- activation-sharding context (set while tracing/lowering on a mesh) ----
_ACTIVE: list = []


@contextlib.contextmanager
def activation_sharding(mesh, rules):
    """Enable ``constrain`` during tracing (dry-run lowering / training)."""
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def constrain(x, *axes):
    """with_sharding_constraint by logical axes; no-op outside the context.
    Dims that don't divide evenly are silently left unsharded (e.g. batch=1
    for long_500k)."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    entries = []
    for dim, a in enumerate(axes):
        phys = rules.get(a) if a is not None else None
        if phys is None:
            entries.append(None)
            continue
        names = (phys,) if isinstance(phys, str) else tuple(phys)
        n = 1
        for nm in names:
            n *= mesh.shape[nm]
        entries.append(phys if x.shape[dim] % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(cfg, tp: int, dp_axes: tuple) -> dict:
    ep_ok = cfg.n_experts > 0 and cfg.n_experts % tp == 0
    return {
        "fsdp": dp_axes,
        "tensor": "model",
        "tensor_q": "model" if (cfg.n_heads and cfg.n_heads % tp == 0)
        else None,
        "tensor_kv": "model" if (cfg.n_kv_heads and cfg.n_kv_heads % tp == 0)
        else None,
        "expert": "model" if ep_ok else None,
        "expert_ff": None if ep_ok else (
            "model" if (cfg.expert_d_ff and cfg.expert_d_ff % tp == 0)
            else None),
        # tensor-mode MoE (grok: 8 experts < tp): sharding the capacity rows
        # over DP removes 9x replicated expert flops but XLA then routes the
        # buffers with expensive gathers — net loss on the step bound, so
        # opt-in (§Perf cell D; a shard_map manual-a2a dispatch is the
        # documented future fix).
        "moe_cap": dp_axes if (not ep_ok and getattr(
            cfg, "moe_cap_shard", False)) else None,
    }


def make_rules_for_mesh(cfg, mesh) -> dict:
    return make_rules(cfg, mesh.shape["model"], data_axes(mesh))


def batch_pspec(mesh, global_batch: int) -> P:
    """Batch sharding: over (pod, data) when divisible, else data, else
    replicated (long_500k batch=1)."""
    axes = data_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if global_batch % n == 0:
        return P(axes)
    if global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def seq_pspec(mesh, cfg, seq_len: int, batch_sharded: bool) -> P | None:
    """Sequence-parallel spec for long sequences when batch can't shard."""
    if batch_sharded:
        return None
    if seq_len % mesh.shape["data"] == 0:
        return P(None, "data")
    return None
