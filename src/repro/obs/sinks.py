"""First-party bus sinks: in-memory, append-only JSONL, and callback.

The sink contract (OBSERVABILITY.md) is one method::

    on_event(ev: dict) -> None       # called on the emitting thread
    close() -> None                  # optional; flush + release resources

Sinks must be cheap — they run inline between a campaign's device
dispatches — and must never assume a particular event mix (unknown
``kind``\\ s are normal; the schema grows).
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from typing import Callable

from .bus import SCHEMA_VERSION


class MemorySink:
    """Buffers every event in order (tests + ad-hoc analysis)."""

    def __init__(self):
        self.events: list[dict] = []

    def on_event(self, ev: dict) -> None:
        self.events.append(ev)

    def close(self) -> None:
        pass

    def kinds(self) -> list[str]:
        return [e["kind"] for e in self.events]

    def of(self, *kinds: str) -> list[dict]:
        want = set(kinds)
        return [e for e in self.events if e["kind"] in want]


class CallbackSink:
    """Routes every event to a callable (dashboards, tee-ing, filters)."""

    def __init__(self, fn: Callable[[dict], None]):
        self.fn = fn

    def on_event(self, ev: dict) -> None:
        self.fn(ev)

    def close(self) -> None:
        pass


class JsonlSink:
    """Append-only JSONL event log — the campaign's durable artifact.

    Line 1 is a header event carrying the schema version and run
    context (``obs.meta``); every later line is one emitted event,
    verbatim.  The format is deliberately boring: committable, diffable,
    streamable (``tail -f``), and the input both the Perfetto exporter
    and the campaign-HTML renderer accept.

    Non-JSON-safe payload values degrade to ``repr`` instead of killing
    the campaign (the bus would swallow the error, but a half-written
    line would corrupt the log).
    """

    def __init__(self, path: str, flush_every: int = 1):
        self.path = str(path)
        self._fh = open(self.path, "a")
        self._lock = threading.Lock()
        self._n = 0
        self.flush_every = max(1, int(flush_every))
        self._write({"kind": "obs.meta", "v": SCHEMA_VERSION,
                     "ts": time.time(), "pid": os.getpid(),
                     "argv": list(sys.argv)})

    def _write(self, ev: dict) -> None:
        try:
            line = json.dumps(ev, sort_keys=False)
        except (TypeError, ValueError):
            line = json.dumps({k: (v if _jsonable(v) else repr(v))
                               for k, v in ev.items()})
        with self._lock:
            self._fh.write(line + "\n")
            self._n += 1
            if self._n % self.flush_every == 0:
                self._fh.flush()

    def on_event(self, ev: dict) -> None:
        self._write(ev)

    def flush(self) -> None:
        with self._lock:
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.flush()
                self._fh.close()


def _jsonable(v) -> bool:
    try:
        json.dumps(v)
        return True
    except (TypeError, ValueError):
        return False


def read_jsonl(path: str, require_version: bool = True) -> list[dict]:
    """Load an event log written by :class:`JsonlSink`.

    Returns the events *without* the header line; raises ``ValueError``
    on a schema-version mismatch (``require_version=False`` skips the
    check for logs from other producers).  Blank/truncated trailing
    lines are tolerated — a live campaign's log is readable mid-write.
    """
    events: list[dict] = []
    header = None
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                continue              # torn tail of a live log
            if header is None and ev.get("kind") == "obs.meta":
                header = ev
                continue
            events.append(ev)
    if require_version:
        if header is None:
            raise ValueError(f"{path}: no obs.meta header line")
        if int(header.get("v", -1)) != SCHEMA_VERSION:
            raise ValueError(
                f"{path}: schema v{header.get('v')} != v{SCHEMA_VERSION}")
    return events
