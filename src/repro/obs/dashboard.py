"""The live campaign dashboard: ``/campaign`` JSON + ``/events`` SSE.

AkitaRTM watches one running simulation (``core/monitor.py``); a DSE
campaign is hundreds of simulations streamed through rounds, and what a
user needs mid-flight is campaign-level state: rounds drained, live and
pending lanes, throughput, budget burn-down, the current best per
objective.  :class:`CampaignServer` attaches to the telemetry bus as a
sink and serves exactly that over the same stdlib HTTP machinery the
monitor uses (:class:`~repro.core.monitor.HttpEndpoint` — ephemeral-port
fallback, clean shutdown):

* ``GET /campaign``  — one JSON snapshot (:meth:`CampaignStats.snapshot`);
* ``GET /events``    — Server-Sent Events: recent-event replay, then the
  live stream as rounds drain (``data:`` lines of schema-v1 events);
* ``GET /metrics``   — the bus metrics registry, rendered to JSON;
* ``GET /``          — a minimal self-refreshing HTML view of /campaign.

Everything is read-only and snapshot-based: HTTP threads never touch
simulation state, so a slow client can never stall a round.
"""
from __future__ import annotations

import collections
import json
import queue
import threading
import time

from repro.core.monitor import HttpEndpoint

from .bus import BUS, SCHEMA_VERSION, Bus

_RATE_WINDOW = 32      # events per rate estimate (rounds / tells)


class CampaignStats:
    """Streaming aggregation of bus events into one dashboard snapshot.

    Consumes the sweep/search event catalogue (OBSERVABILITY.md) —
    unknown kinds only bump the event counter, so the aggregator keeps
    working as the schema grows.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.started = time.time()
        self.updated = self.started
        self.events = 0
        self.rounds = 0
        self.sweeps = 0
        self.lanes = {"live": 0, "pending": 0, "pool": 0}
        self.epochs_total = 0
        self._round_hist = collections.deque(maxlen=_RATE_WINDOW)
        self.compiles = {"count": 0, "dur_total": 0.0}
        self.transfers = {"count": 0, "dur_total": 0.0}
        self.search = {"driver": None, "objective": None, "round": 0,
                       "trials": 0, "budget": 0.0, "cycle_budget": None,
                       "best": None, "done": False}
        self._tell_hist = collections.deque(maxlen=_RATE_WINDOW)
        self.promotions = []           # last few rung.promote payloads
        self.cache = {"hits": 0, "misses": 0, "writes": 0,
                      "bytes_read": 0, "bytes_written": 0,
                      "evictions": 0, "bytes_evicted": 0, "dir": None}
        self.shards = {"devices": 1, "rebalances": 0, "lanes_moved": 0}
        self.pipeline = {"depth": 1, "overlap_frac": None,
                         "host_s_total": 0.0, "wait_s_total": 0.0}
        self.mux = {"runs": 0, "jobs": 0}
        # the round timeline: one compact entry per drained round, the
        # raw material for a per-round occupancy strip chart
        self._timeline = collections.deque(maxlen=120)

    # ------------------------------------------------------------------
    def on_event(self, ev: dict) -> None:
        with self._lock:
            self._update(ev)

    def _update(self, ev: dict) -> None:
        self.events += 1
        self.updated = ev.get("ts", time.time())
        kind = ev.get("kind", "")
        if kind == "round.end":
            self.rounds += 1
            self.lanes = {"live": int(ev.get("survivors", 0)),
                          "pending": int(ev.get("pending", 0)),
                          "pool": int(ev.get("pool", 0))}
            self.epochs_total += int(ev.get("epochs", 0))
            self._round_hist.append((ev["ts"], int(ev.get("epochs", 0))))
            self.pipeline["host_s_total"] += float(ev.get("host_s", 0.0))
            self.pipeline["wait_s_total"] += float(ev.get("wait_s", 0.0))
            if ev.get("overlap_frac") is not None:
                self.pipeline["overlap_frac"] = float(ev["overlap_frac"])
            self._timeline.append(
                {k: ev.get(k) for k in ("round", "rung", "dur", "host_s",
                                        "wait_s", "overlap_frac", "inflight",
                                        "finished", "survivors", "epochs",
                                        "probe", "endgame")})
        elif kind == "sweep.end":
            self.sweeps += 1
            self.lanes = {"live": 0, "pending": 0, "pool": 0}
        elif kind == "compile":
            self.compiles["count"] += int(ev.get("n", 1))
            self.compiles["dur_total"] += float(ev.get("dur", 0.0))
        elif kind == "transfer":
            self.transfers["count"] += 1
            self.transfers["dur_total"] += float(ev.get("dur", 0.0))
        elif kind == "search.start":
            self.search.update(driver=ev.get("driver"),
                               objective=ev.get("objective"),
                               cycle_budget=ev.get("cycle_budget"),
                               done=False)
        elif kind == "search.tell":
            self.search["round"] = int(ev.get("round", 0)) + 1
            self.search["trials"] += int(ev.get("n", 0))
            self.search["budget"] = float(ev.get("budget", 0.0))
            if ev.get("best") is not None:
                self.search["best"] = ev["best"]
            self._tell_hist.append((ev["ts"], self.search["budget"]))
        elif kind == "search.end":
            self.search["done"] = True
            if ev.get("best") is not None:
                self.search["best"] = ev["best"]
        elif kind == "rung.promote":
            self.promotions.append(
                {k: ev.get(k) for k in ("bracket", "rung", "horizon",
                                        "promoted", "dropped", "warm",
                                        "spent", "replay_cycles")})
            del self.promotions[:-8]
        elif kind == "cache.hit":
            self.cache["hits"] += 1
            self.cache["bytes_read"] += int(ev.get("bytes", 0))
        elif kind == "cache.miss":
            self.cache["misses"] += 1
        elif kind == "cache.write":
            self.cache["writes"] += 1
            self.cache["bytes_written"] += int(ev.get("bytes", 0))
        elif kind == "cache.evict":
            self.cache["evictions"] += 1
            self.cache["bytes_evicted"] += int(ev.get("bytes", 0))
        elif kind == "cache.enable":
            self.cache["dir"] = ev.get("dir")
        elif kind == "mux.start":
            self.mux["runs"] += 1
            self.mux["jobs"] += len(ev.get("jobs") or ())
        elif kind == "shard.rebalance":
            self.shards["devices"] = int(ev.get("shards", 1))
            self.shards["rebalances"] += 1
            self.shards["lanes_moved"] += int(ev.get("moved", 0))
        elif kind == "rounds.start":
            self.shards["devices"] = int(ev.get("shard", 1))
            self.pipeline["depth"] = int(ev.get("pipeline", 1))

    @staticmethod
    def _rate(hist) -> float:
        """Units/sec over the recent window of (ts, increment|total)."""
        if len(hist) < 2:
            return 0.0
        dt = hist[-1][0] - hist[0][0]
        return 0.0 if dt <= 0 else sum(v for _, v in list(hist)[1:]) / dt

    def snapshot(self) -> dict:
        with self._lock:
            now = time.time()
            budget = self.search["budget"]
            cap = self.search["cycle_budget"]
            tells = list(self._tell_hist)
            cycles_per_sec = 0.0
            if len(tells) >= 2:
                dt = tells[-1][0] - tells[0][0]
                if dt > 0:
                    cycles_per_sec = (tells[-1][1] - tells[0][1]) / dt
            return {
                "schema": SCHEMA_VERSION,
                "started": self.started,
                "updated": self.updated,
                "uptime": now - self.started,
                "events": self.events,
                "rounds_drained": self.rounds,
                "sweeps": self.sweeps,
                "lanes": dict(self.lanes),
                "epochs": {"total": self.epochs_total,
                           "per_sec": self._rate(self._round_hist)},
                "cycles": {"spent": budget, "cap": cap,
                           "remaining": (None if cap is None
                                         else max(cap - budget, 0.0)),
                           "burn_fraction": (None if not cap
                                             else min(budget / cap, 1.0)),
                           "per_sec": cycles_per_sec},
                "compiles": dict(self.compiles),
                "transfers": dict(self.transfers),
                "cache": dict(
                    self.cache,
                    hit_rate=(self.cache["hits"]
                              / (self.cache["hits"] + self.cache["misses"])
                              if self.cache["hits"] + self.cache["misses"]
                              else None)),
                "shards": dict(self.shards),
                "pipeline": dict(
                    self.pipeline,
                    run_overlap_frac=(
                        self.pipeline["host_s_total"]
                        / (self.pipeline["host_s_total"]
                           + self.pipeline["wait_s_total"])
                        if self.pipeline["host_s_total"]
                        + self.pipeline["wait_s_total"] > 0 else None)),
                "mux": dict(self.mux),
                "round_timeline": list(self._timeline),
                "search": dict(self.search),
                "promotions": list(self.promotions),
            }


_INDEX_HTML = """<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>campaign</title>
<style>body{font-family:monospace;margin:16px;background:#fafafa}
pre{background:#fff;border:1px solid #ddd;padding:12px}</style></head>
<body><h3>DSE campaign</h3>
<h4>round timeline (recent; # = overlap)</h4><pre id="t">-</pre>
<pre id="s">loading...</pre>
<script>
function bar(f){const n=Math.round((f||0)*20);
  return '#'.repeat(n)+'.'.repeat(20-n);}
function timeline(rows){
  return rows.slice(-24).map(r=>
    `r${String(r.round).padStart(4)} rung=${String(r.rung).padStart(4)} `+
    `${(r.dur||0).toFixed(3)}s host=${(r.host_s||0).toFixed(3)}s `+
    `wait=${(r.wait_s||0).toFixed(3)}s [${bar(r.overlap_frac)}] `+
    `infl=${r.inflight}${r.endgame?' end':''}${r.probe?' probe':''}`
  ).join('\\n')||'-';}
async function tick(){
  try{const r=await fetch('/campaign');const j=await r.json();
      document.getElementById('t').textContent=
        timeline(j.round_timeline||[]);
      document.getElementById('s').textContent=
        JSON.stringify(j,null,2);}catch(e){}
  setTimeout(tick,1000);}
tick();
</script></body></html>
"""


class CampaignServer:
    """Serve live campaign telemetry from a bus over HTTP.

    Attaching the server to a bus is what switches it on — it is itself
    a sink: every event updates :class:`CampaignStats`, lands in a
    bounded replay ring, and is fanned out to connected SSE clients
    through per-client bounded queues (a stalled client drops events,
    it never backpressures the campaign).

    ``port`` is a request; the bound port is on ``self.port``
    (ephemeral fallback — see :class:`~repro.core.monitor.HttpEndpoint`).
    """

    def __init__(self, bus: Bus | None = None, port: int = 0,
                 history: int = 512, attach: bool = True):
        self.bus = bus if bus is not None else BUS
        self.stats = CampaignStats()
        self._ring: collections.deque = collections.deque(maxlen=history)
        self._clients: list[queue.Queue] = []
        self._lock = threading.Lock()
        self._closed = threading.Event()
        srv = self

        from http.server import BaseHTTPRequestHandler

        class H(BaseHTTPRequestHandler):
            def _json(self, body, code=200):
                data = json.dumps(body).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                if path == "/campaign":
                    self._json(srv.stats.snapshot())
                elif path == "/metrics":
                    self._json(srv.bus.metrics.snapshot())
                elif path == "/events":
                    self._sse()
                elif path == "/":
                    data = _INDEX_HTML.encode()
                    self.send_response(200)
                    self.send_header("Content-Type",
                                     "text/html; charset=utf-8")
                    self.send_header("Content-Length", str(len(data)))
                    self.end_headers()
                    self.wfile.write(data)
                else:
                    self._json({"error": "not found",
                                "endpoints": ["/", "/campaign", "/events",
                                              "/metrics"]}, code=404)

            def _sse(self):
                self.send_response(200)
                self.send_header("Content-Type", "text/event-stream")
                self.send_header("Cache-Control", "no-cache")
                self.end_headers()
                q = srv._subscribe()
                try:
                    while not srv._closed.is_set():
                        try:
                            ev = q.get(timeout=0.25)
                        except queue.Empty:
                            self.wfile.write(b": keepalive\n\n")
                            self.wfile.flush()
                            continue
                        self.wfile.write(
                            f"data: {json.dumps(ev)}\n\n".encode())
                        self.wfile.flush()
                except (BrokenPipeError, ConnectionResetError, OSError):
                    pass
                finally:
                    srv._unsubscribe(q)

            def log_message(self, *a):
                pass

        self.endpoint = HttpEndpoint(H, port=port)
        self.port = self.endpoint.port
        self.url = self.endpoint.url
        if attach:
            self.bus.attach(self)

    # -- sink interface -----------------------------------------------------
    def on_event(self, ev: dict) -> None:
        self.stats.on_event(ev)
        with self._lock:
            self._ring.append(ev)
            clients = list(self._clients)
        for q in clients:
            try:
                q.put_nowait(ev)
            except queue.Full:       # stalled client: drop, never block
                pass

    def close(self) -> None:
        self.bus.detach(self)
        self._closed.set()
        self.endpoint.shutdown()

    # -- SSE plumbing -------------------------------------------------------
    def _subscribe(self) -> queue.Queue:
        q: queue.Queue = queue.Queue(maxsize=1024)
        with self._lock:
            for ev in self._ring:    # replay recent history on connect
                try:
                    q.put_nowait(ev)
                except queue.Full:
                    break
            self._clients.append(q)
        return q

    def _unsubscribe(self, q: queue.Queue) -> None:
        with self._lock:
            self._clients = [c for c in self._clients if c is not q]
