"""The campaign telemetry bus: ``emit(kind, **fields)`` + a metrics
registry, with pluggable sinks.

Akita's tracing story (paper §3.4–3.6) covers a *single engine run*:
``start_task``/``end_task`` annotations flow to tracers, AkitaRTM watches
a live simulation, Daisen renders the trace afterwards.  Everything the
DSE stack does *between* engine runs — rounds, lane compaction, chunk
autotuning, compiles, ask/tell search rounds, rung promotions, budget
spend — happened in the dark.  This module is the campaign-side
counterpart: one process-wide :class:`Bus` that the sweep/search
machinery emits structured events into, and that sinks (JSONL files,
the live ``/campaign`` dashboard, the Perfetto exporter) consume.

Design constraints, in order:

* **Zero-cost when disabled.**  ``Bus.emit`` returns before building the
  event when no sink is attached, and every instrumented call site
  guards payload assembly with ``if BUS.active:`` — a telemetry-off
  sweep materializes *zero* events (the monotonic ``seq`` counter does
  not advance; pinned by ``tests/obs``).
* **Host-side only.**  Emission happens strictly between jitted
  dispatches — never inside a traced function — so telemetry can never
  change compiled programs or results: a telemetry-on sweep's rows are
  bit-identical to a telemetry-off run (gated in ``BENCH_trace.json``).
* **Flat, versioned events.**  An event is a flat dict with three
  reserved keys — ``kind`` (dotted event name), ``ts`` (wall-clock
  epoch seconds), ``seq`` (process-monotonic) — plus event-specific
  fields; completed spans carry ``dur`` (seconds).  The schema version
  (:data:`SCHEMA_VERSION`) rides the JSONL header and the event
  catalogue lives in OBSERVABILITY.md.

Sinks implement a single method ``on_event(ev: dict)`` (and optionally
``close()``); a sink that raises is detached-in-place semantics-free —
the error is recorded on ``Bus.sink_errors`` and the campaign keeps
running (telemetry must never kill the work it watches).
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Any

SCHEMA_VERSION = 1

MAX_SINK_ERRORS = 16     # keep the first few, drop the rest


# ---------------------------------------------------------------------------
class Counter:
    """A monotonically increasing count (events seen, trials run)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """A point-in-time value (live lanes, budget spent)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming summary of an observed quantity (round durations,
    transfer times): count / total / min / max / last."""

    __slots__ = ("count", "total", "min", "max", "last")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.last = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if v < self.min else self.min
        self.max = v if v > self.max else self.max
        self.last = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Named counters/gauges/histograms, created on first use.

    One registry rides the bus; ``snapshot()`` renders every metric to a
    JSON-safe dict (what ``/campaign`` serves under ``"metrics"``).
    """

    def __init__(self):
        self._metrics: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls())
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        out = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[name] = {"count": m.count, "total": m.total,
                             "mean": m.mean, "last": m.last,
                             "min": None if m.count == 0 else m.min,
                             "max": None if m.count == 0 else m.max}
            else:
                out[name] = m.value
        return out

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
class Bus:
    """The event fan-out: ``emit`` builds one event dict and hands it to
    every attached sink, under a lock (sinks may be mutated from the
    dashboard's HTTP threads)."""

    def __init__(self):
        self._sinks: list = []
        self._emitted = 0
        self._lock = threading.Lock()
        self.metrics = MetricsRegistry()
        self.sink_errors: list[tuple[str, str]] = []

    # -- sink management ----------------------------------------------------
    @property
    def active(self) -> bool:
        """True when at least one sink is attached — the one flag every
        instrumented call site checks before assembling a payload."""
        return bool(self._sinks)

    def attach(self, sink):
        with self._lock:
            self._sinks.append(sink)
        return sink

    def detach(self, sink) -> None:
        with self._lock:
            self._sinks = [s for s in self._sinks if s is not sink]

    @property
    def seq(self) -> int:
        """Events materialized so far (the disabled-path zero-cost pin:
        a telemetry-off run leaves this unchanged)."""
        return self._emitted

    # -- emission -----------------------------------------------------------
    def emit(self, kind: str, **fields) -> dict | None:
        """Materialize and fan out one event; no-op (returns ``None``)
        while no sink is attached."""
        if not self._sinks:
            return None
        with self._lock:
            seq = self._emitted
            self._emitted += 1
        ev = {"kind": kind, "ts": time.time(), "seq": seq}
        ev.update(fields)
        with self._lock:
            sinks = list(self._sinks)
        for s in sinks:
            try:
                s.on_event(ev)
            except Exception as e:   # telemetry never kills the campaign
                if len(self.sink_errors) < MAX_SINK_ERRORS:
                    self.sink_errors.append((type(s).__name__, repr(e)))
        return ev

    @contextlib.contextmanager
    def span(self, kind: str, **fields):
        """Emit ``kind`` as a completed span on exit (``dur`` = wall
        seconds inside the block).  Payload fields may be added by
        mutating the yielded dict."""
        extra: dict = dict(fields)
        t0 = time.perf_counter()
        try:
            yield extra
        finally:
            if self._sinks:
                self.emit(kind, dur=time.perf_counter() - t0, **extra)

    # -- metric sugar (guarded: no-ops while inactive) ----------------------
    def count(self, name: str, n: float = 1.0) -> None:
        if self._sinks:
            self.metrics.counter(name).inc(n)

    def gauge(self, name: str, v: float) -> None:
        if self._sinks:
            self.metrics.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        if self._sinks:
            self.metrics.histogram(name).observe(v)


# The process-wide default bus.  The DSE stack emits here; attach a sink
# (JSONL / dashboard / memory) to switch a campaign's telemetry on.
BUS = Bus()

emit = BUS.emit


def capture(bus: Bus | None = None):
    """Context manager: attach a fresh in-memory sink for the block and
    return it (``with capture() as sink: ... sink.events``)."""
    from .sinks import MemorySink

    b = bus if bus is not None else BUS

    @contextlib.contextmanager
    def _ctx():
        sink = MemorySink()
        b.attach(sink)
        try:
            yield sink
        finally:
            b.detach(sink)

    return _ctx()
