"""Bridge engine-side :class:`~repro.core.tracing.Task`\\ s onto the
campaign bus — one event stream covers both clocks.

The engine's tracing domains (paper §3.4) timestamp tasks on *their*
clock: host domains use wall time, simulation domains use virtual time.
:class:`BusTracer` is an ordinary tracer (attach it to any
:class:`~repro.core.tracing.TracingDomain`, with the usual filter
predicate) that re-emits completed tasks as ``task`` events tagged with
the domain name and clock, so a campaign's JSONL log interleaves engine
tasks with round/search events and the Perfetto export can render both
— campaign wall-time tracks next to engine task tracks.

Event shape (schema v1)::

    {"kind": "task", "domain": ..., "clock": "wall"|"virtual",
     "id", "parent_id", "category", "action", "location",
     "start", "end", "dur", "tags", "ts", "seq"}

``start``/``end``/``dur`` are in the domain's own clock units;
``ts``/``seq`` are the bus's wall clock and ordering, as for every
event.
"""
from __future__ import annotations

from typing import Callable

from repro.core.tracing import Task, TracingDomain

from .bus import BUS, Bus


class BusTracer:
    """A tracer that forwards completed tasks to a telemetry bus."""

    def __init__(self, bus: Bus | None = None, domain: str = "engine",
                 clock: str = "virtual"):
        assert clock in ("wall", "virtual"), clock
        self.bus = bus if bus is not None else BUS
        self.domain = domain
        self.clock = clock

    # tracer interface (repro.core.tracers._Base shape) ------------------
    def on_start(self, t: Task) -> None:
        pass

    def on_end(self, t: Task) -> None:
        if not self.bus.active:
            return
        end = t.start if t.end is None else t.end
        self.bus.emit("task", domain=self.domain, clock=self.clock,
                      id=t.id, parent_id=t.parent_id,
                      category=t.category, action=t.action,
                      location=t.location, start=t.start, end=end,
                      dur=end - t.start, tags=list(t.tags))

    def on_tag(self, t: Task, tag: str) -> None:
        if self.bus.active:
            self.bus.count(f"tag.{tag}")


def bridge_domain(domain: TracingDomain, bus: Bus | None = None,
                  clock: str = "wall",
                  filter: Callable[[Task], bool] | None = None) -> BusTracer:
    """Attach a :class:`BusTracer` to ``domain`` and return it (detach
    with ``domain.detach(tracer)``)."""
    tracer = BusTracer(bus, domain=domain.name, clock=clock)
    domain.attach(tracer, filter=filter)
    return tracer
