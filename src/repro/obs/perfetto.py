"""Post-campaign visualization: Chrome-trace (Perfetto) export and a
Daisen-lite campaign timeline.

:func:`export_chrome_trace` renders a campaign's event stream (a list of
schema-v1 events or a :class:`~repro.obs.sinks.JsonlSink` log path) into
the Chrome trace-event JSON format — load it at https://ui.perfetto.dev
(or ``chrome://tracing``).  Campaign activity maps onto named tracks of
one "campaign" process:

* **rounds**    — one slice per drained round (rung size, live lanes,
  finished/survivor counts, quantum in ``args``);
* **compile**   — retrace/compile occurrences with durations;
* **transfer**  — ``device_get`` pulls (liveness vectors, result rows);
* **search**    — one slice per ask→tell search round, budget in args;
* **bracket b** — rung-promotion instants (promoted/dropped counts,
  warm-vs-cold cost) per halving bracket;
* **checkpoint** — search checkpoint save/load slices;
* counter tracks — ``budget`` (cycles spent) and ``lanes``
  (live/pending), rendered by Perfetto as area charts.

Engine tasks bridged onto the bus (:mod:`repro.obs.bridge`) land in a
second "engine" process with one track per task location — virtual-time
clocks stay separate from the campaign's wall clock instead of being
spliced onto it.

:func:`export_campaign_html` renders the same stream through the
Daisen-lite HTML timeline (:mod:`repro.core.daisen`) — no Perfetto
needed, one self-contained file.
"""
from __future__ import annotations

import json

from repro.core.daisen import export_html
from repro.core.tracing import Task

from .sinks import read_jsonl

_PID_CAMPAIGN = 1
_PID_ENGINE = 2

_TID_ROUNDS = 1
_TID_COMPILE = 2
_TID_TRANSFER = 3
_TID_SEARCH = 4
_TID_CHECKPOINT = 5
_TID_TRIALS = 6
_TID_BRACKET0 = 16         # bracket b -> tid 16 + b
_TID_ENGINE0 = 1           # engine locations -> tid 1.. in pid 2


def _load(events) -> list[dict]:
    if isinstance(events, (str, bytes)):
        return read_jsonl(events)
    return list(events)


def _meta(pid: int, tid: int | None, name: str) -> dict:
    ev = {"ph": "M", "pid": pid, "ts": 0,
          "name": "thread_name" if tid is not None else "process_name",
          "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    else:
        ev["tid"] = 0
    return ev


def _x(name: str, pid: int, tid: int, start_s: float, dur_s: float,
       args: dict) -> dict:
    return {"ph": "X", "name": name, "pid": pid, "tid": tid,
            "ts": start_s * 1e6, "dur": max(dur_s, 0.0) * 1e6,
            "args": args}


def _instant(name: str, pid: int, tid: int, ts_s: float,
             args: dict) -> dict:
    return {"ph": "i", "s": "t", "name": name, "pid": pid, "tid": tid,
            "ts": ts_s * 1e6, "args": args}


def _counter(name: str, ts_s: float, values: dict) -> dict:
    return {"ph": "C", "name": name, "pid": _PID_CAMPAIGN, "tid": 0,
            "ts": ts_s * 1e6, "args": values}


def _args(ev: dict, skip=("kind", "ts", "seq", "dur")) -> dict:
    return {k: v for k, v in ev.items()
            if k not in skip and _scalarish(v)}


def _scalarish(v) -> bool:
    return isinstance(v, (int, float, str, bool, type(None))) or (
        isinstance(v, (list, tuple)) and len(v) <= 16)


def to_chrome_trace(events) -> dict:
    """Build the trace dict (``{"traceEvents": [...]}``) from a
    schema-v1 event stream."""
    events = _load(events)
    out: list[dict] = [
        _meta(_PID_CAMPAIGN, None, "dse-campaign"),
        _meta(_PID_CAMPAIGN, _TID_ROUNDS, "rounds"),
        _meta(_PID_CAMPAIGN, _TID_COMPILE, "compile"),
        _meta(_PID_CAMPAIGN, _TID_TRANSFER, "transfer"),
        _meta(_PID_CAMPAIGN, _TID_SEARCH, "search"),
        _meta(_PID_CAMPAIGN, _TID_CHECKPOINT, "checkpoint"),
        _meta(_PID_CAMPAIGN, _TID_TRIALS, "trials"),
    ]
    asks: dict[int, dict] = {}          # search round -> ask event
    brackets: set[int] = set()
    engine_tids: dict[str, int] = {}

    for ev in events:
        kind = ev.get("kind", "")
        ts = float(ev.get("ts", 0.0))
        dur = float(ev.get("dur", 0.0))
        if kind == "round.end":
            out.append(_x(f"round {ev.get('round', '?')} "
                          f"(C={ev.get('rung', '?')})",
                          _PID_CAMPAIGN, _TID_ROUNDS, ts - dur, dur,
                          _args(ev)))
            out.append(_counter("lanes", ts,
                                {"live": ev.get("survivors", 0),
                                 "pending": ev.get("pending", 0)}))
        elif kind == "compile":
            out.append(_x(f"compile b={ev.get('b', '?')}",
                          _PID_CAMPAIGN, _TID_COMPILE, ts - dur, dur,
                          _args(ev)))
        elif kind == "transfer":
            out.append(_x(f"transfer:{ev.get('what', '?')}",
                          _PID_CAMPAIGN, _TID_TRANSFER, ts - dur, dur,
                          _args(ev)))
        elif kind == "search.ask":
            asks[int(ev.get("round", -1))] = ev
        elif kind == "search.tell":
            r = int(ev.get("round", -1))
            ask = asks.pop(r, None)
            start = float(ask["ts"]) if ask else ts
            args = _args(ev)
            if ask:
                args.update({f"ask_{k}": v for k, v in _args(ask).items()
                             if k not in args})
            out.append(_x(f"search round {r}", _PID_CAMPAIGN,
                          _TID_SEARCH, start, ts - start, args))
            out.append(_counter("budget", ts,
                                {"cycles": ev.get("budget", 0.0)}))
        elif kind == "trial":
            out.append(_instant("trial", _PID_CAMPAIGN, _TID_TRIALS,
                                ts, _args(ev)))
        elif kind == "rung.promote":
            b = int(ev.get("bracket", 0))
            if b not in brackets:
                brackets.add(b)
                out.append(_meta(_PID_CAMPAIGN, _TID_BRACKET0 + b,
                                 f"bracket {b}"))
            out.append(_instant(f"rung {ev.get('rung', '?')} promote",
                                _PID_CAMPAIGN, _TID_BRACKET0 + b, ts,
                                _args(ev)))
        elif kind in ("ckpt.save", "ckpt.load"):
            out.append(_x(kind, _PID_CAMPAIGN, _TID_CHECKPOINT,
                          ts - dur, dur, _args(ev)))
        elif kind == "task":
            loc = str(ev.get("location", "?"))
            tid = engine_tids.get(loc)
            if tid is None:
                tid = engine_tids[loc] = _TID_ENGINE0 + len(engine_tids)
                if len(engine_tids) == 1:
                    out.append(_meta(_PID_ENGINE, None, "engine"))
                out.append(_meta(_PID_ENGINE, tid, loc))
            start = float(ev.get("start", ts))
            end = float(ev.get("end", start))
            out.append(_x(f"{ev.get('category', '?')}/"
                          f"{ev.get('action', '?')}",
                          _PID_ENGINE, tid, start, end - start,
                          _args(ev, skip=("kind", "ts", "seq", "dur",
                                          "start", "end"))))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_chrome_trace(events, out_path: str) -> str:
    """Write the Chrome-trace JSON for ``events`` (a list or a JSONL log
    path) to ``out_path``; load the file in Perfetto."""
    with open(out_path, "w") as fh:
        json.dump(to_chrome_trace(events), fh)
    return out_path


# ---------------------------------------------------------------------------
def campaign_tasks(events) -> list[Task]:
    """Map a campaign event stream onto :class:`~repro.core.tracing.Task`
    rows for the Daisen-lite renderer — one lane per activity class,
    wall times rebased to the first event."""
    events = _load(events)
    wall = [float(e["ts"]) for e in events if "ts" in e]
    t0 = min(wall) if wall else 0.0
    tasks: list[Task] = []
    n = 0

    def add(category, action, location, start, end, **details):
        nonlocal n
        n += 1
        tasks.append(Task(id=f"c{n:08x}", parent_id="",
                          category=category, action=action,
                          location=location, start=start, end=end,
                          details=details))

    for ev in events:
        kind = ev.get("kind", "")
        ts = float(ev.get("ts", t0)) - t0
        dur = float(ev.get("dur", 0.0))
        if kind == "round.end":
            add("round", f"C={ev.get('rung', '?')}", "rounds",
                ts - dur, ts, round=ev.get("round"),
                finished=ev.get("finished"), survivors=ev.get("survivors"))
        elif kind == "compile":
            add("compile", f"b={ev.get('b', '?')}", "compile",
                ts - dur, ts)
        elif kind == "transfer":
            add("transfer", str(ev.get("what", "?")), "transfer",
                ts - dur, ts)
        elif kind == "search.tell":
            add("search", f"round {ev.get('round', '?')}", "search",
                ts - dur if dur else ts, ts,
                budget=ev.get("budget"), n=ev.get("n"))
        elif kind == "rung.promote":
            add("promote", f"rung {ev.get('rung', '?')}",
                f"bracket {ev.get('bracket', 0)}", ts, ts,
                promoted=ev.get("promoted"), dropped=ev.get("dropped"))
        elif kind == "task":
            start = float(ev.get("start", 0.0))
            end = float(ev.get("end", start))
            add(str(ev.get("category", "?")), str(ev.get("action", "?")),
                f"engine/{ev.get('location', '?')}", start, end)
    return tasks


def export_campaign_html(events, out_path: str,
                         title: str = "campaign timeline") -> str:
    """Render the Daisen-lite campaign timeline HTML for ``events`` (a
    list or a JSONL log path)."""
    return export_html(campaign_tasks(events), out_path, title=title)
