"""repro.obs — campaign telemetry: one event/metrics bus for sweeps and
search, with pluggable sinks, a live dashboard, and Perfetto export.

The engine already honors the paper's observability pitch for single
runs (tracing §3.4, AkitaRTM §3.5, Daisen §3.6); this package gives DSE
*campaigns* — round-based sweeps, closed-loop searches — the same
first-class treatment:

  * :mod:`~repro.obs.bus`       — the process-wide :class:`Bus`
    (``emit(kind, **fields)``), the metrics registry
    (counters/gauges/histograms) and the schema version.  Zero-cost
    when no sink is attached; host-side only, never inside jitted code.
  * :mod:`~repro.obs.sinks`     — :class:`MemorySink`,
    :class:`JsonlSink` (versioned append-only event log),
    :class:`CallbackSink`, and :func:`read_jsonl`.
  * :mod:`~repro.obs.bridge`    — :class:`BusTracer`: forward engine
    :class:`~repro.core.tracing.Task`\\ s onto the bus so one stream
    covers engine (virtual) and campaign (wall) clocks.
  * :mod:`~repro.obs.dashboard` — :class:`CampaignServer`: live
    ``/campaign`` JSON + ``/events`` SSE over the monitor's HTTP
    machinery (rounds drained, live/pending lanes, budget burn-down,
    current best per objective).
  * :mod:`~repro.obs.perfetto`  — :func:`export_chrome_trace`
    (Perfetto-loadable trace-event JSON: rounds/compiles/transfers/
    search rounds/rung promotions as tracks) and
    :func:`export_campaign_html` (Daisen-lite campaign timeline).

The instrumented call sites live in ``repro.dse`` (runner, sweep,
search drivers) — see OBSERVABILITY.md for the event catalogue and
DSE.md "Watching a campaign" for the workflow.
"""
from .bridge import BusTracer, bridge_domain
from .bus import (BUS, SCHEMA_VERSION, Bus, Counter, Gauge, Histogram,
                  MetricsRegistry, capture, emit)
from .dashboard import CampaignServer, CampaignStats
from .perfetto import (campaign_tasks, export_campaign_html,
                       export_chrome_trace, to_chrome_trace)
from .sinks import CallbackSink, JsonlSink, MemorySink, read_jsonl

__all__ = [
    "BUS", "SCHEMA_VERSION", "Bus", "BusTracer", "CallbackSink",
    "CampaignServer", "CampaignStats", "Counter", "Gauge", "Histogram",
    "JsonlSink", "MemorySink", "MetricsRegistry", "bridge_domain",
    "campaign_tasks", "capture", "emit", "export_campaign_html",
    "export_chrome_trace", "read_jsonl", "to_chrome_trace",
]
