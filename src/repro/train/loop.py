"""Fault-tolerant training loop.

Production behaviours, scaled down to one process but structured for
thousands of nodes (DESIGN.md §5):

* deterministic resume — the data pipeline is a pure function of step, the
  RNG is derived per step, so kill/restart reproduces the uninterrupted run
  bit-exactly (asserted in tests/test_train_loop.py);
* periodic + signal-triggered checkpoints (SIGTERM drains and saves before
  exit — preemption-safe);
* per-step watchdog: steps exceeding ``watchdog_factor``× the EWMA step
  time are flagged (the single-process stand-in for straggler mitigation;
  on a real cluster this feeds the coordinator's replace/restart decision);
* the whole loop is instrumented with the paper's task tracing — every
  step/data-fetch/checkpoint is a task in the same DB that the engine's
  simulations write, and AkitaRTM-style progress lines come for free.
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core.tracing import TracingDomain
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.optim import adamw_init

from .step import TrainHParams, make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "runs/ckpt"
    keep: int = 3
    log_every: int = 10
    watchdog_factor: float = 4.0
    seed: int = 0


def train(cfg, data_fn, loop: LoopConfig, hp: TrainHParams | None = None,
          domain: TracingDomain | None = None, resume: bool = True,
          params=None, opt_state=None):
    """Returns (params, opt_state, history)."""
    hp = hp or TrainHParams(donate=False)
    dom = domain or TracingDomain("train")
    mgr = CheckpointManager(loop.ckpt_dir, keep=loop.keep)
    step_fn = jax.jit(make_train_step(cfg, hp))

    if params is None:
        params = init_params(tfm.model_specs(cfg),
                             jax.random.PRNGKey(loop.seed))
        opt_state = adamw_init(params, moments_dtype=hp.moments_dtype)
    start = 0
    if resume and mgr.latest_step() is not None:
        with dom.task("checkpoint", "restore", "ckpt"):
            state, manifest = mgr.restore({"p": params, "o": opt_state})
        params, opt_state = state["p"], state["o"]
        start = manifest["step"] + 1
        print(f"[resume] restored step {manifest['step']}")

    stop = {"flag": False}
    prev = signal.getsignal(signal.SIGTERM)

    def on_term(sig, frame):
        stop["flag"] = True

    signal.signal(signal.SIGTERM, on_term)

    history = []
    ewma = None
    try:
        for step in range(start, loop.steps):
            with dom.task("train", "step", "loop", step=step):
                with dom.task("data", "fetch", "pipeline"):
                    batch = {k: jax.numpy.asarray(v)
                             for k, v in data_fn(step).items()}
                t0 = time.perf_counter()
                loss, gnorm, params, opt_state = step_fn(params, opt_state,
                                                         batch)
                loss = float(loss)
                dt = time.perf_counter() - t0
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}")
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            straggler = dt > loop.watchdog_factor * ewma
            if straggler:
                dom.tag_task("straggler-step")
                print(f"[watchdog] step {step} took {dt:.2f}s "
                      f"(ewma {ewma:.2f}s) — straggler flagged")
            history.append({"step": step, "loss": loss,
                            "gnorm": float(gnorm), "dt": dt})
            if step % loop.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(gnorm):.3f} {dt*1e3:.0f}ms")
            if (step + 1) % loop.ckpt_every == 0 or stop["flag"] or \
                    step + 1 == loop.steps:
                with dom.task("checkpoint", "save", "ckpt", step=step):
                    mgr.save({"p": params, "o": opt_state}, step)
            if stop["flag"]:
                print(f"[signal] SIGTERM: drained and checkpointed at "
                      f"step {step}")
                break
        mgr.wait()
    finally:
        signal.signal(signal.SIGTERM, prev)
    return params, opt_state, history
