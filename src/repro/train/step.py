"""train_step factory: loss -> grads (accumulated over microbatches) ->
clip -> AdamW, with full logical-axis shardings for the production meshes.

The same factory serves real CPU training (tests/examples, tiny configs) and
the multi-pod dry-run (abstract params/batch, ``.lower().compile()`` only).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.layers import (abstract_params, init_params,
                                 make_pspecs, make_shardings)
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.parallel.sharding import (batch_pspec, data_axes,
                                     make_rules_for_mesh)


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    micro_batches: int = 1
    moments_dtype: str = "float32"     # "int8" => 8-bit optimizer states
    donate: bool = True


def make_train_step(cfg, hp: TrainHParams):
    """Returns train_step(params, opt_state, batch) -> (loss, params, opt)."""

    def loss_fn(params, batch):
        return tfm.train_loss(params, cfg, batch)

    def train_step(params, opt_state, batch):
        if hp.micro_batches > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            def split(x):
                return x.reshape((hp.micro_batches,
                                  x.shape[0] // hp.micro_batches) +
                                 x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)),
                                           mbs)
            grads = jax.tree.map(lambda g: g / hp.micro_batches, gsum)
            loss = lsum / hp.micro_batches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, hp.grad_clip)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=hp.lr,
            weight_decay=hp.weight_decay, moments_dtype=hp.moments_dtype)
        return loss, gnorm, params, opt_state

    return train_step


# ---------------------------------------------------------------------------
# sharding assembly for a concrete mesh
# ---------------------------------------------------------------------------
def opt_pspecs(param_pspecs, moments_dtype="float32"):
    """Optimizer-state PartitionSpecs mirror the parameter sharding (ZeRO-3:
    moments fully sharded the same way as their parameters)."""
    def mom(ps):
        if moments_dtype == "int8":
            # int8 blocks flatten the tensor; shard the block axis on the
            # first parameter axis's assignment when possible, else replicate
            return {"q": P(ps[0] if len(ps) else None),
                    "s": P(ps[0] if len(ps) else None)}
        return ps

    return {
        "m": jax.tree.map(mom, param_pspecs,
                          is_leaf=lambda x: isinstance(x, P)),
        "v": jax.tree.map(mom, param_pspecs,
                          is_leaf=lambda x: isinstance(x, P)),
        "count": P(),
    }


def batch_pspecs(cfg, mesh, shape):
    """PartitionSpecs for the input batch of a given assigned shape."""
    bp = batch_pspec(mesh, shape.global_batch)
    specs = {}
    if cfg.frontend == "audio":
        specs["features"] = P(*bp, None, None)
        specs["labels"] = P(*bp, None)
        specs["mask"] = P(*bp, None)
    elif cfg.frontend == "vision":
        specs["tokens"] = P(*bp, None)
        specs["vision"] = P(*bp, None, None)
    else:
        specs["tokens"] = P(*bp, None)
    return specs


def assemble_train(cfg, mesh, shape, hp: TrainHParams | None = None):
    """Abstract args + jitted train_step with shardings, ready to lower."""
    hp = hp or TrainHParams()
    rules = make_rules_for_mesh(cfg, mesh)
    specs = tfm.model_specs(cfg)
    p_pspecs = make_pspecs(specs, rules)
    params = abstract_params(specs)
    opt_shape = jax.eval_shape(
        partial(adamw_init, moments_dtype=hp.moments_dtype), params)
    o_pspecs = opt_pspecs(p_pspecs, hp.moments_dtype)
    b_pspecs = batch_pspecs(cfg, mesh, shape)
    batch = abstract_batch(cfg, shape)

    step = make_train_step(cfg, hp)
    jitted = jax.jit(
        step,
        in_shardings=(jax.tree.map(lambda s: NamedSharding(mesh, s), p_pspecs),
                      jax.tree.map(lambda s: NamedSharding(mesh, s), o_pspecs,
                                   is_leaf=lambda x: isinstance(x, P)),
                      jax.tree.map(lambda s: NamedSharding(mesh, s), b_pspecs)),
        out_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                       jax.tree.map(lambda s: NamedSharding(mesh, s), p_pspecs),
                       jax.tree.map(lambda s: NamedSharding(mesh, s), o_pspecs,
                                    is_leaf=lambda x: isinstance(x, P))),
        donate_argnums=(0, 1) if hp.donate else ())
    return jitted, (params, opt_shape, batch)


def abstract_batch(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": f((B, 1), jnp.int32)}
    if cfg.frontend == "audio":
        return {"features": f((B, S, cfg.frontend_dim), jnp.float32),
                "labels": f((B, S), jnp.int32),
                "mask": f((B, S), jnp.float32)}
    if cfg.frontend == "vision":
        nv = cfg.n_vision_tokens
        return {"tokens": f((B, S - nv), jnp.int32),
                "vision": f((B, nv, cfg.d_model), jnp.float32)}
    return {"tokens": f((B, S), jnp.int32)}
