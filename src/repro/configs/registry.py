"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "deepseek-67b", "gemma2-27b", "phi3-medium-14b", "stablelm-1.6b",
    "hubert-xlarge", "deepseek-v2-236b", "grok-1-314b", "hymba-1.5b",
    "mamba2-130m", "internvl2-26b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch])


def get_config(arch: str, **overrides):
    import dataclasses
    cfg = _mod(arch).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(arch: str):
    return _mod(arch).smoke_config()
