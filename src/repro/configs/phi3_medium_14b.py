"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab=100352, act="swiglu", norm="rms",
    rope_theta=10_000.0,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="phi3-medium-14b-smoke", n_layers=3, d_model=60,
        n_heads=5, n_kv_heads=5, head_dim=12, d_ff=128, vocab=128)
