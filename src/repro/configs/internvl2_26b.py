"""internvl2-26b [vlm] — InternLM2-20B backbone, ViT patch-embedding stub
(256 precomputed vision tokens) [arXiv:2404.16821; hf]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92553, act="swiglu", norm="rms",
    frontend="vision", n_vision_tokens=256, rope_theta=10_000.0,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="internvl2-26b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
        n_vision_tokens=4)
