"""hubert-xlarge [audio] — encoder-only; precomputed frame-embedding stub
frontend [arXiv:2106.07447]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504, act="gelu", norm="ln", causal=False,
    frontend="audio", frontend_dim=512,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="hubert-xlarge-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=64,
        frontend_dim=32)
