"""stablelm-1.6b [dense] — MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab=100352, act="swiglu", norm="ln",
    rope_theta=10_000.0,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="stablelm-1.6b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=128)
