"""hymba-1.5b [hybrid] — parallel attn+mamba heads, SWA except 3 global
layers, ssm_state=16 [arXiv:2411.13676; hf]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, act="swiglu", norm="rms",
    window=1024, attn_pattern="global3",
    ssm_state=16, ssm_headdim=64, ssm_expand=2, conv_kernel=4,
    rope_theta=10_000.0,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="hymba-1.5b-smoke", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
        window=8, ssm_state=8, ssm_headdim=16, ssm_chunk=8)
