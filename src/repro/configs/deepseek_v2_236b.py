"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6,
layer-0 dense [arXiv:2405.04434; hf]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab=102400, act="swiglu", norm="rms",
    n_experts=160, n_shared_experts=2, top_k=6, expert_d_ff=1536,
    first_dense_d_ff=12288,
    use_mla=True, q_lora=1536, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128, rope_theta=10_000.0,
    # tuned defaults from EXPERIMENTS.md §Perf cell B (baseline = moe_groups
    # 1 / capacity 1.25, preserved in runs/dryrun): 4.6x less collective wire
    moe_groups=32, moe_capacity=1.0,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="deepseek-v2-236b-smoke", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32, vocab=128,
        n_experts=8, n_shared_experts=1, top_k=2, expert_d_ff=32,
        first_dense_d_ff=96, q_lora=48, kv_lora=32, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16)
