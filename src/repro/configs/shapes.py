"""Assigned input shapes × applicability matrix (see DESIGN.md
§Arch-applicability for every skip and its reason)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    sh = SHAPES[shape_name]
    if sh.kind == "decode" and not cfg.causal:
        return False, "encoder-only arch has no autoregressive decode"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: O(S) per decoded token at "
                       "S=524288 with no sub-quadratic path (DESIGN.md)")
    return True, ""


def cell_list(arch_ids, get_config):
    """All (arch, shape) cells with status."""
    cells = []
    for a in arch_ids:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = applicable(cfg, s)
            cells.append((a, s, ok, why))
    return cells
