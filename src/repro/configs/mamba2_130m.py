"""mamba2-130m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280, norm="rms", tie_embeddings=True,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, conv_kernel=4,
    ssm_chunk=256,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="mamba2-130m-smoke", n_layers=2, d_model=64,
        vocab=128, ssm_state=16, ssm_headdim=16, ssm_chunk=8)
