"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072, act="gelu", norm="rms",
    n_experts=8, top_k=2, expert_d_ff=32768,
    attn_softcap=30.0, final_softcap=30.0, rope_theta=10_000.0,
    # group-wise dispatch is a win here too; capacity-row sharding is NOT
    # (EXPERIMENTS.md §Perf cell D: confirmed flops fix, net wire loss) —
    # moe_cap_shard stays False pending a shard_map manual-a2a dispatch.
    moe_groups=32,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="grok-1-314b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
        n_experts=4, top_k=2, expert_d_ff=64)
