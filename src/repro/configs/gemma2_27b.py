"""gemma2-27b [dense] — local+global alternating, logit softcaps
[arXiv:2408.00118; hf]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000, act="gelu", norm="rms",
    attn_softcap=50.0, final_softcap=30.0, window=4096, attn_pattern="alt",
    tie_embeddings=True, rope_theta=10_000.0,
)


def smoke_config():
    return dataclasses.replace(
        CONFIG, name="gemma2-27b-smoke", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=128, window=8)
