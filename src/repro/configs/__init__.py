"""Assigned architecture configs (exact, from the task sheet) + reduced
smoke variants + shape registry."""
from .registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
from .shapes import SHAPES, applicable, cell_list  # noqa: F401
