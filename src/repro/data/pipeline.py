"""Deterministic, restart-safe, elastically-sharded data pipeline.

Every batch is a pure function of (seed, step) — no iterator state to
checkpoint, so restart/resume is exact (tests assert bit-equality) and
elastic rescaling only changes which *slice* of the global batch each
data-parallel rank materializes.  A byte-level tokenizer + packed text
corpus path feeds the runnable examples; the synthetic stream feeds
benchmarks and large-scale runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def synthetic_batch(cfg, B: int, S: int, seed: int, step: int,
                    rank: int = 0, world: int = 1):
    """Global-batch slice for this rank: rows [rank*B/world, ...)."""
    assert B % world == 0
    b_local = B // world
    out = {}
    rows = []
    for r in range(rank * b_local, (rank + 1) * b_local):
        rng = np.random.Generator(np.random.Philox(key=seed,
                                                   counter=[0, 0, step, r]))
        rows.append(rng)
    if cfg.frontend == "audio":
        out["features"] = np.stack([r.standard_normal(
            (S, cfg.frontend_dim), dtype=np.float32) for r in rows])
        out["labels"] = np.stack([r.integers(0, cfg.vocab, S).astype(np.int32)
                                  for r in rows])
        out["mask"] = np.stack([(r.random(S) < 0.3).astype(np.float32)
                                for r in rows])
    elif cfg.frontend == "vision":
        nv = cfg.n_vision_tokens
        out["tokens"] = np.stack([r.integers(0, cfg.vocab, S - nv)
                                  .astype(np.int32) for r in rows])
        out["vision"] = np.stack([r.standard_normal(
            (nv, cfg.d_model), dtype=np.float32) for r in rows])
    else:
        # markovian-ish synthetic tokens (learnable structure, not uniform)
        toks = []
        for r in rows:
            base = r.integers(0, cfg.vocab, S // 8 + 1).astype(np.int32)
            t = np.repeat(base, 8)[:S]                 # local repetition
            noise = r.integers(0, cfg.vocab, S).astype(np.int32)
            m = r.random(S) < 0.15
            toks.append(np.where(m, noise, t))
        out["tokens"] = np.stack(toks)
    return out


class ByteTokenizer:
    vocab_size = 256

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        return bytes(int(i) % 256 for i in ids).decode("utf-8", "replace")


@dataclasses.dataclass
class DataPipeline:
    """step -> batch; stateless beyond (seed, corpus)."""

    cfg: object
    batch: int
    seq: int
    seed: int = 0
    rank: int = 0
    world: int = 1
    corpus: np.ndarray | None = None       # packed token stream (optional)

    @staticmethod
    def from_text(cfg, text: str, batch: int, seq: int, **kw):
        toks = ByteTokenizer().encode(text) % cfg.vocab
        return DataPipeline(cfg, batch, seq, corpus=toks, **kw)

    def __call__(self, step: int) -> dict:
        if self.corpus is None:
            return synthetic_batch(self.cfg, self.batch, self.seq, self.seed,
                                   step, self.rank, self.world)
        # packed contiguous windows, deterministic stride per step+row
        n = len(self.corpus) - self.seq - 1
        b_local = self.batch // self.world
        rows = []
        for r in range(self.rank * b_local, (self.rank + 1) * b_local):
            off = (step * self.batch + r) * 977 % max(n, 1)
            rows.append(self.corpus[off:off + self.seq])
        return {"tokens": np.stack(rows).astype(np.int32)}
