from .pipeline import (ByteTokenizer, DataPipeline,  # noqa: F401
                       synthetic_batch)
