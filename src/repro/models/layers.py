"""Parameter-spec machinery + basic layers (norms, MLPs, embeddings).

Parameters are declared as :class:`PSpec` trees carrying logical sharding
axes (``'fsdp'``, ``'tensor'`` or ``None`` per dim).  The same tree serves
three uses:

* ``init_params``      — materialize real arrays (tests, examples, training);
* ``abstract_params``  — ShapeDtypeStructs for the multi-pod dry-run;
* ``make_shardings``   — NamedShardings for a concrete mesh via axis rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple
    axes: tuple          # logical axis name per dim: 'fsdp' | 'tensor' | None
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaves(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, PSpec))


def init_params(tree, key, dtype=jnp.bfloat16):
    leaves = _leaves(tree)
    keys = jax.random.split(key, len(leaves))
    it = iter(keys)

    def one(ps: PSpec):
        k = next(it)
        if ps.init == "zeros":
            return jnp.zeros(ps.shape, dtype)
        if ps.init == "ones":
            return jnp.ones(ps.shape, dtype)
        scale = ps.scale if ps.scale is not None else \
            1.0 / math.sqrt(max(ps.shape[0], 1))
        return (jax.random.normal(k, ps.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, PSpec))


def abstract_params(tree, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, dtype), tree,
        is_leaf=lambda x: isinstance(x, PSpec))


def partition_spec(ps: PSpec, rules: dict) -> P:
    return P(*[rules.get(a) if a is not None else None for a in ps.axes])


def make_shardings(tree, mesh, rules: dict):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, partition_spec(ps, rules)), tree,
        is_leaf=lambda x: isinstance(x, PSpec))


def make_pspecs(tree, rules: dict):
    return jax.tree.map(lambda ps: partition_spec(ps, rules), tree,
                        is_leaf=lambda x: isinstance(x, PSpec))


def stack_layers(spec_fn, n_layers: int):
    """Stack per-layer PSpec trees along a new leading (scan) axis."""
    one = spec_fn()
    return jax.tree.map(
        lambda ps: PSpec((n_layers,) + ps.shape, (None,) + ps.axes,
                         ps.init, ps.scale),
        one, is_leaf=lambda x: isinstance(x, PSpec))


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b=None, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    n = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    if b is not None:
        n = n + b.astype(jnp.float32)
    return n.astype(x.dtype)


def norm(cfg, x, w):
    return rmsnorm(x, w) if cfg.norm == "rms" else layernorm(x, w)


def norm_spec(cfg):
    init = "zeros" if cfg.norm == "rms" else "ones"
    return PSpec((cfg.d_model,), (None,), init)


def mlp_specs(d_model: int, d_ff: int, act: str):
    if act == "swiglu":
        return {
            "wi": PSpec((d_model, d_ff), ("fsdp", "tensor")),
            "wg": PSpec((d_model, d_ff), ("fsdp", "tensor")),
            "wo": PSpec((d_ff, d_model), ("tensor", "fsdp")),
        }
    return {
        "wi": PSpec((d_model, d_ff), ("fsdp", "tensor")),
        "wo": PSpec((d_ff, d_model), ("tensor", "fsdp")),
    }


def mlp(params, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    else:
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x


def embed_specs(cfg):
    s = {"tok": PSpec((cfg.vocab_padded, cfg.d_model), ("tensor", "fsdp"),
                      scale=1.0)}
    if not cfg.tie_embeddings:
        s["unembed"] = PSpec((cfg.d_model, cfg.vocab_padded),
                             ("fsdp", "tensor"))
    if cfg.frontend == "audio":
        s["frontend_proj"] = PSpec((cfg.frontend_dim, cfg.d_model),
                                   (None, "fsdp"))
    return s


def embed(params, cfg, tokens):
    e = jnp.take(params["tok"], tokens, axis=0)
    if cfg.norm == "rms" and cfg.final_softcap:   # gemma-style scaling
        e = e * jnp.asarray(math.sqrt(cfg.d_model), e.dtype)
    return e


def unembed(params, cfg, x):
    w = params["tok"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ w.astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab:  # mask padding columns
        pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad, -1e30, logits)
    return logits


def cross_entropy(logits, labels, mask=None, z_loss: float = 0.0):
    """Mean token cross-entropy (f32), optional validity mask + z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(loss)
