"""Mamba-2 SSD (state-space duality) — chunked train/prefill + O(1) decode.

The chunked dual form turns the recurrence into MXU-friendly matmuls:
within-chunk terms are a masked attention-like product, across-chunk state is
a short ``lax.scan``.  ``ssd_ref`` is the sequential oracle used by tests and
by the Pallas kernel's allclose sweep.  Decode keeps (conv_state, ssm_state)
per layer — O(1) in context length, which is what qualifies mamba2/hymba for
the ``long_500k`` shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import PSpec, rmsnorm


def ssm_specs(cfg):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = di + 2 * N
    return {
        "in_proj": PSpec((d, 2 * di + 2 * N + H), ("fsdp", None)),
        "conv_w": PSpec((cfg.conv_kernel, conv_dim), (None, None),
                        scale=0.5),
        "conv_b": PSpec((conv_dim,), (None,), "zeros"),
        "A_log": PSpec((H,), (None,), "zeros"),
        "D": PSpec((H,), (None,), "ones"),
        "dt_bias": PSpec((H,), (None,), "zeros"),
        "norm_w": PSpec((di,), (None,), "zeros"),
        "out_proj": PSpec((di, d), (None, "fsdp")),
    }


def _split(cfg, zxbcdt):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _conv(cfg, xBC, conv_w, conv_b):
    """Depthwise causal conv over sequence. xBC: [B, S, conv_dim]."""
    K = cfg.conv_kernel
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + xBC.shape[1], :] *
              conv_w[k].astype(xBC.dtype) for k in range(K))
    return jax.nn.silu(out + conv_b.astype(xBC.dtype))


def ssd_chunked(xs, dt, A, B_, C_, chunk: int):
    """Chunked SSD. xs:[B,S,H,P] dt:[B,S,H] A:[H] B_,C_:[B,S,N].
    Returns y:[B,S,H,P] and final state [B,H,P,N]."""
    B, S, H, Pd = xs.shape
    N = B_.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, "sequence must be divisible by ssm_chunk"
    r = lambda t: t.reshape((B, nc, chunk) + t.shape[2:])
    xs_, dt_, Bc, Cc = r(xs), r(dt), r(B_), r(C_)

    a = (dt_.astype(jnp.float32) * A.astype(jnp.float32))   # [B,nc,l,H]
    cum = jnp.cumsum(a, axis=2)                              # within-chunk
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # [B,nc,i,j,H]
    li = jnp.arange(chunk)
    causal = (li[:, None] >= li[None, :])[None, None, :, :, None]
    # mask BEFORE exp: future entries have positive seg that overflows, and
    # where(mask, exp(seg), 0) then yields inf*0 = NaN in the backward pass
    L = jnp.exp(jnp.where(causal, seg, -1e30))

    # intra-chunk: y[i] = sum_j (C_i·B_j) L[i,j] dt_j x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    scores = cb[:, :, :, :, None] * L * dt_[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xs_.astype(jnp.float32))

    # per-chunk state contribution: S_c = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)             # [B,nc,l,H]
    contrib = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                         decay_out * dt_, Bc.astype(jnp.float32),
                         xs_.astype(jnp.float32))
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))                # [B,nc,H]

    def scan_fn(h, inp):
        contrib_c, dec_c = inp
        h2 = h * dec_c[:, :, None, None] + contrib_c
        return h2, h

    h0 = jnp.zeros((B, H, Pd, N), jnp.float32)
    hT, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (contrib.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)               # [B,nc,H,P,N]

    # inter-chunk: y[i] += C_i · (h_prev * exp(cum_i))
    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc.astype(jnp.float32),
                         h_prevs) * jnp.exp(cum)[:, :, :, :, None]
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y.astype(xs.dtype), hT


def ssd_ref(xs, dt, A, B_, C_):
    """Sequential oracle: h_t = h_{t-1} e^{A dt_t} + dt_t B_t x_t^T."""
    B, S, H, Pd = xs.shape
    N = B_.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dec = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))
        h = h * dec[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt_t.astype(jnp.float32),
            b_t.astype(jnp.float32), x_t.astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", c_t.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, Pd, N), jnp.float32)
    hT, ys = jax.lax.scan(
        step, h0, (xs.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                   B_.transpose(1, 0, 2), C_.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(xs.dtype), hT


def ssm_block(params, cfg, x, *, cache=None):
    """Full Mamba-2 block.  x: [B, S, d].

    Train/prefill (cache=None): chunked SSD over the sequence; returns
    (out, None) — or (out, (conv_state, ssm_state)) if ``cache == "init"``
    to produce a decode cache from prefill.
    Decode: cache = (conv_state [B,K-1,conv_dim], ssm_state [B,H,P,N]),
    S must be 1; returns (out, new_cache).
    """
    B, S, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_headdim
    K = cfg.conv_kernel
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xBC, dt = _split(cfg, zxbcdt)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    decode = cache is not None and cache != "init"
    if not decode:
        xBC = _conv(cfg, xBC, params["conv_w"], params["conv_b"])
        xs = xBC[..., :di].reshape(B, S, H, Pd)
        B_, C_ = xBC[..., di:di + N], xBC[..., di + N:]
        if cfg.attn_impl in ("pallas", "pallas_interpret") and S >= cfg.ssm_chunk:
            from repro.kernels.ssd import ops as ssd_ops
            y, hT = ssd_ops.ssd(xs, dt, A, B_, C_, cfg.ssm_chunk,
                                interpret=cfg.attn_impl == "pallas_interpret")
        elif S >= cfg.ssm_chunk and S % cfg.ssm_chunk == 0:
            y, hT = ssd_chunked(xs, dt, A, B_, C_, cfg.ssm_chunk)
        else:
            y, hT = ssd_ref(xs, dt, A, B_, C_)
        new_cache = None
        if cache == "init":
            raw = x @ params["in_proj"].astype(x.dtype)
            _, xBC_raw, _ = _split(cfg, raw)
            pad = jnp.pad(xBC_raw, ((0, 0), (K - 1, 0), (0, 0)))
            conv_state = pad[:, -(K - 1):, :]
            new_cache = (conv_state, hT)
    else:
        conv_state, h = cache
        assert S == 1
        # depthwise conv against the rolling window
        win = jnp.concatenate([conv_state, xBC], axis=1)      # [B,K,conv]
        conv_out = jnp.einsum("bkc,kc->bc", win,
                              params["conv_w"].astype(x.dtype)) \
            + params["conv_b"].astype(x.dtype)
        xBC1 = jax.nn.silu(conv_out)[:, None, :]
        xs = xBC1[..., :di].reshape(B, 1, H, Pd)
        B_, C_ = xBC1[..., di:di + N], xBC1[..., di + N:]
        dec = jnp.exp(dt[:, 0] * A)                           # [B,H]
        h = h * dec[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0], B_[:, 0].astype(jnp.float32),
            xs[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32),
                       h)[:, None].astype(x.dtype)
        new_cache = (win[:, 1:, :], h)

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    return y @ params["out_proj"].astype(x.dtype), new_cache
