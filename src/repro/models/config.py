"""Model configuration covering all assigned architecture families.

One dataclass drives dense / MoE / SSM / hybrid / audio / VLM variants; the
family decides which blocks `transformer.py` assembles.  Exact assigned
configs live in ``repro/configs/<arch>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    vocab: int
    # attention (H=0 for attention-free archs)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    # mlp
    d_ff: int = 0
    act: Literal["swiglu", "gelu"] = "swiglu"
    norm: Literal["rms", "ln"] = "rms"
    rope_theta: float = 10_000.0
    # gemma2-style extras
    attn_softcap: float = 0.0          # 0 = off
    final_softcap: float = 0.0
    window: int = 0                    # sliding-window size; 0 = full attention
    # per-layer attention pattern: "full", "alt" (local/global alternating),
    # "global3" (global at first/middle/last, SWA elsewhere)
    attn_pattern: str = "full"
    causal: bool = True                # False => encoder-only (hubert)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    moe_capacity: float = 1.25
    first_dense_d_ff: int = 0          # deepseek-v2: layer 0 is dense
    # MLA (deepseek-v2)
    use_mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_kernel: int = 4
    # modality frontends (stubs per assignment)
    frontend: Literal["none", "audio", "vision"] = "none"
    frontend_dim: int = 0              # audio: raw frame feature dim
    n_vision_tokens: int = 0           # vlm: precomputed patch embeddings
    # numerics / perf knobs
    dtype: str = "bfloat16"
    remat: str = "block"               # none | block | full
    attn_impl: str = "xla"             # xla | pallas | pallas_interpret
    scan_layers: bool = True
    attn_probs_bf16: bool = False      # bf16 P·V accumulate (perf knob)
    moe_groups: int = 1                # group-wise dispatch (shard-local
    #                                    capacity/cumsum, GShard-style)
    moe_cap_shard: bool = False        # tensor-mode MoE: shard capacity
    #                                    rows over DP (saves 9x flops, costs
    #                                    a2a wire — §Perf cell D trade)
    # optimizer-relevant size helpers ------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding to a shardable multiple of 256."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def has_attn(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if decode cost per token is o(S) in context length S for all
        (or all but O(1)) layers — gate for the long_500k shape."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True  # SWA + 3 global layers (documented in DESIGN.md)
        return False

    def layer_windows(self) -> list[int]:
        """Per-layer attention window (0 = full/global)."""
        if not self.has_attn:
            return [0] * self.n_layers
        if self.attn_pattern == "alt":
            return [self.window if i % 2 == 0 else 0
                    for i in range(self.n_layers)]
        if self.attn_pattern == "global3":
            g = {0, self.n_layers // 2, self.n_layers - 1}
            return [0 if i in g else self.window
                    for i in range(self.n_layers)]
        return [self.window] * self.n_layers

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (analytic; embeddings included once if tied)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        n = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.has_attn:
            if self.use_mla:
                qd = self.qk_nope_dim + self.qk_rope_dim
                per_layer += d * self.q_lora + self.q_lora * self.n_heads * qd
                per_layer += d * (self.kv_lora + self.qk_rope_dim)
                per_layer += self.kv_lora * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim)
                per_layer += self.n_heads * self.v_head_dim * d
            else:
                hd = self.head_dim
                per_layer += d * self.n_heads * hd          # q
                per_layer += 2 * d * self.n_kv_heads * hd   # k, v
                per_layer += self.n_heads * hd * d          # o
        if self.has_ssm:
            di, g, N = self.d_inner, 1, self.ssm_state
            conv_dim = di + 2 * g * N
            per_layer += d * (2 * di + 2 * g * N + self.n_ssm_heads)
            per_layer += self.conv_kernel * conv_dim
            per_layer += 3 * self.n_ssm_heads               # A, D, dt_bias
            per_layer += di * d
        if self.n_experts:
            e_ff = self.expert_d_ff
            per_layer += self.n_experts * 3 * d * e_ff      # routed (swiglu)
            per_layer += self.n_shared_experts * 3 * d * e_ff
            per_layer += d * self.n_experts                 # router
        elif self.d_ff:
            mult = 3 if self.act == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        per_layer += 2 * d                                  # norms
        n += L * per_layer
        if self.first_dense_d_ff:  # deepseek-v2 layer-0 dense replaces MoE
            e_ff = self.expert_d_ff
            moe_l0 = (self.n_experts + self.n_shared_experts) * 3 * d * e_ff \
                + d * self.n_experts
            n += 3 * d * self.first_dense_d_ff - moe_l0
        if self.frontend == "audio":
            n += self.frontend_dim * d
        return int(n)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        inactive_per_layer = (self.n_experts - self.top_k) * 3 * d * \
            self.expert_d_ff
        n_inactive = self.n_layers * inactive_per_layer
        if self.first_dense_d_ff:
            n_inactive -= inactive_per_layer
        return int(self.param_count() - n_inactive)
