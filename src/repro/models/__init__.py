"""repro.models — the framework model zoo (assigned architectures)."""
