"""GQA attention: RoPE, causal/sliding-window/alternating masks, logit
softcap, and a blockwise online-softmax implementation (the "xla" path).

The blockwise path scans over KV chunks carrying (max, denom, acc) — flash
attention expressed in pure jnp.  It is numerically identical to the Pallas
kernel (kernels/flash_attention) and serves as its oracle; it also keeps the
compiled HLO's peak temp at O(S·chunk) instead of O(S²), which matters for
the dry-run memory analysis.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .layers import PSpec, softcap

NEG = -1e30


def rope(x, positions, theta: float):
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [..., S, half]
    ang = ang[..., None, :]                                   # [..., S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def attn_specs(cfg):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": PSpec((d, H, hd), ("fsdp", "tensor_q", None)),
        "wk": PSpec((d, KV, hd), ("fsdp", "tensor_kv", None)),
        "wv": PSpec((d, KV, hd), ("fsdp", "tensor_kv", None)),
        "wo": PSpec((H, hd, d), ("tensor_q", None, "fsdp")),
    }


def _mask(q_pos, kv_pos, causal, window):
    """q_pos [B,Sq], kv_pos [B,Sk] -> bool [B,Sq,Sk]; kv_pos<0 = invalid.

    ``window`` may be a traced scalar (per-layer alternating patterns inside
    a layer scan); <=0 means full attention.
    """
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    m = kp >= 0
    if causal:
        m &= kp <= qp
    w = jnp.asarray(window, jnp.int32)
    m &= (w <= 0) | (qp - kp < w)
    return m


def blockwise_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                        cap=0.0, scale=None, chunk=1024, probs_bf16=False):
    """Online-softmax attention.

    q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; q_pos: [B,Sq]; kv_pos: [B,Sk]
    (kv_pos < 0 marks invalid cache slots).  Returns [B,Sq,H,hd].
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]                       # MLA: v head dim != qk head dim
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.reshape(B, Sq, KV, G, hd)

    def scores_of(kc, kvp):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc,
                       preferred_element_type=jnp.float32) * scale
        if cap:
            s = softcap(s, cap)
        m = _mask(q_pos, kvp, causal, window)           # [B,Sq,ck]
        return jnp.where(m[:, None, None, :, :], s, NEG)

    if Sk <= chunk:
        s = scores_of(k, kv_pos)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
        return o.reshape(B, Sq, H, hd_v)

    n = -(-Sk // chunk)
    pad = n * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    ks = k.reshape(B, n, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n, chunk, KV, hd_v).transpose(1, 0, 2, 3, 4)
    ps = kv_pos.reshape(B, n, chunk).transpose(1, 0, 2)

    m0 = jnp.full((B, KV, G, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd_v), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, kvp = inp
        s = scores_of(kc, kvp)                           # [B,KV,G,Sq,ck]
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + jnp.sum(p, axis=-1)
        if probs_bf16:   # perf knob: halve P·V read traffic (post-max safe)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(jnp.bfloat16),
                            vc.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        acc2 = acc * corr[..., None] + pv
        return (m2, l2, acc2), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd_v).astype(q.dtype)


def attention_block(params, cfg, x, q_pos, *, window, cache=None,
                    cache_len=None):
    """Full attention sub-block: qkv proj, rope, attend, out proj.

    Training/prefill: cache=None -> self-attention over x.
    Decode: cache=(k_cache [B,S,KV,hd], v_cache) with new token(s) written at
    q_pos; returns (out, new_cache).
    """
    B, Sq, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dke->bske", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dke->bske", x, params["wv"].astype(x.dtype))
    q = rope(q, q_pos, cfg.rope_theta)
    k = rope(k, q_pos, cfg.rope_theta)

    if cache is None:
        kv_pos = q_pos
        kk, vv = k, v
        new_cache = None
    else:
        ck, cv = cache
        S = ck.shape[1]
        # write new kv at positions q_pos (decode: Sq small)
        idx = q_pos.astype(jnp.int32)                       # [B,Sq]
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
        kk = ck.at[bidx, idx].set(k.astype(ck.dtype))
        vv = cv.at[bidx, idx].set(v.astype(cv.dtype))
        new_cache = (kk, vv)
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        limit = (cache_len if cache_len is not None
                 else q_pos[:, -1:] + 1)                    # [B,1]
        kv_pos = jnp.where(pos <= limit - 1, pos, -1)

    if cfg.attn_impl in ("pallas", "pallas_interpret") and cache is None:
        from repro.kernels.flash_attention import ops as fa
        out = fa.flash_attention(
            q, kk, vv, q_pos, kv_pos, causal=cfg.causal, window=window,
            cap=cfg.attn_softcap,
            interpret=cfg.attn_impl == "pallas_interpret")
    else:
        out = blockwise_attention(
            q, kk, vv, q_pos, kv_pos, causal=cfg.causal, window=window,
            cap=cfg.attn_softcap, probs_bf16=cfg.attn_probs_bf16)
    o = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(x.dtype))
    return o, new_cache
