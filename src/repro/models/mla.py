"""Multi-head Latent Attention (DeepSeek-V2) — train + absorbed decode.

MLA compresses KV into a low-rank latent c_kv (kv_lora dims) plus a shared
RoPE key (qk_rope_dim).  Training/prefill materializes per-head K/V from the
latent (matmul-friendly); decode uses the *absorbed* form — the K up-
projection is folded into the query so attention runs directly against the
cached latent, making the KV cache O(kv_lora + rope) per token instead of
O(2·H·hd): 576 vs 32768 floats/token for the assigned config.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .attention import blockwise_attention, rope
from .layers import PSpec


def mla_specs(cfg):
    d, H = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": PSpec((d, cfg.q_lora), ("fsdp", None)),
        "wq_b": PSpec((cfg.q_lora, H, qd), (None, "tensor_q", None)),
        "wkv_a": PSpec((d, cfg.kv_lora + cfg.qk_rope_dim), ("fsdp", None)),
        "wk_b": PSpec((cfg.kv_lora, H, cfg.qk_nope_dim),
                      (None, "tensor_q", None)),
        "wv_b": PSpec((cfg.kv_lora, H, cfg.v_head_dim),
                      (None, "tensor_q", None)),
        "wo": PSpec((H, cfg.v_head_dim, d), ("tensor_q", None, "fsdp")),
        "q_norm": PSpec((cfg.q_lora,), (None,), "zeros"),
        "kv_norm": PSpec((cfg.kv_lora,), (None,), "zeros"),
    }


def _project_q(params, cfg, x, q_pos):
    from .layers import rmsnorm
    B, Sq, _ = x.shape
    H = cfg.n_heads
    qa = rmsnorm(x @ params["wq_a"].astype(x.dtype), params["q_norm"])
    q = jnp.einsum("bsl,lhe->bshe", qa, params["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = rope(q_rope, q_pos, cfg.rope_theta)
    return q_nope, q_rope


def _project_latent(params, cfg, x, pos):
    from .layers import rmsnorm
    kv = x @ params["wkv_a"].astype(x.dtype)
    c_kv = rmsnorm(kv[..., :cfg.kv_lora], params["kv_norm"])
    k_rope = rope(kv[..., cfg.kv_lora:][:, :, None, :], pos, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_block(params, cfg, x, q_pos, *, cache=None, cache_len=None,
              window=0):
    """cache: (c_kv [B,S,kv_lora], k_rope [B,S,rope]) latent cache."""
    B, Sq, _ = x.shape
    H = cfg.n_heads
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = _project_q(params, cfg, x, q_pos)
    c_new, kr_new = _project_latent(params, cfg, x, q_pos)

    if cache is None:
        # Training/prefill: materialize per-head K/V (matmul-heavy, MXU-friendly)
        k_nope = jnp.einsum("bsl,lhe->bshe", c_new,
                            params["wk_b"].astype(x.dtype))
        vv = jnp.einsum("bsl,lhe->bshe", c_new,
                        params["wv_b"].astype(x.dtype))
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_new[:, :, None, :],
                                      (B, Sq, H, cfg.qk_rope_dim))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = blockwise_attention(qq, kk, vv, q_pos, q_pos, causal=cfg.causal,
                                  window=window, scale=scale)
        new_cache = None
    else:
        # Absorbed decode: fold wk_b into q, attend against the latent cache.
        c_c, kr_c = cache
        S = c_c.shape[1]
        idx = q_pos.astype(jnp.int32)
        b = jnp.arange(B, dtype=jnp.int32)[:, None]
        c_c = c_c.at[b, idx].set(c_new.astype(c_c.dtype))
        kr_c = kr_c.at[b, idx].set(kr_new.astype(kr_c.dtype))
        new_cache = (c_c, kr_c)
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        limit = cache_len if cache_len is not None else q_pos[:, -1:] + 1
        kv_pos = jnp.where(pos <= limit - 1, pos, -1)
        valid = (kv_pos >= 0)[:, None, None, :]              # [B,1,1,S]
        # scores = q_nope·(wk_b c) + q_rope·k_rope  — absorb wk_b into q:
        q_abs = jnp.einsum("bshe,lhe->bshl", q_nope,
                           params["wk_b"].astype(x.dtype))   # [B,Sq,H,kv_lora]
        s = (jnp.einsum("bshl,btl->bhst", q_abs, c_c,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshe,bte->bhst", q_rope, kr_c,
                          preferred_element_type=jnp.float32)) * scale
        if cfg.causal:
            causal_m = kv_pos[:, None, None, :] <= idx[:, None, :, None]
            valid = valid & causal_m
        s = jnp.where(valid, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        # out_h = wv_b^T (sum_t p_t c_t): absorb on the value side too
        ctx = jnp.einsum("bhst,btl->bshl", p.astype(x.dtype), c_c)
        out = jnp.einsum("bshl,lhe->bshe", ctx,
                         params["wv_b"].astype(x.dtype))
    o = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(x.dtype))
    return o, new_cache
