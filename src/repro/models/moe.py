"""Expert-parallel Mixture-of-Experts (GShard-style capacity dispatch).

Dispatch is cumsum-based (no distributed sort): tokens pick top-k experts,
per-expert slots are assigned by a running count in *choice-major* order
(all first choices get capacity before second choices), overflow tokens are
dropped to the residual path.  Expert weights are stacked ``[E, ...]`` and
sharded on E when ``E % tp == 0`` (EP — deepseek-v2: 160/16 = 10 experts per
chip); otherwise the expert FFN dim is tensor-sharded (grok-1: 8 experts,
32768-wide FFN over 16 chips).  The token→expert reshard is an XLA-inserted
all_to_all, visible in the roofline's collective term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import PSpec, mlp, mlp_specs


def moe_specs(cfg):
    d, E, eff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    s = {
        "router": PSpec((d, E), (None, None), scale=0.02),
        "we_i": PSpec((E, d, eff), ("expert", "fsdp", "expert_ff")),
        "we_g": PSpec((E, d, eff), ("expert", "fsdp", "expert_ff")),
        "we_o": PSpec((E, eff, d), ("expert", "expert_ff", "fsdp")),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_specs(d, cfg.n_shared_experts * eff, "swiglu")
    return s


def moe_block(params, cfg, x, capacity: int | None = None):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    ``cfg.moe_groups > 1`` switches to group-wise dispatch: the token axis is
    split into G independent groups (aligned with the data shards), each with
    its own capacity and *local* running-count cumsum — removing the global
    sequential dependency that otherwise forces cross-shard gathers of the
    [K·T, E] dispatch tensors (GShard local groups; §Perf hillclimb)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = max(cfg.moe_groups, 1)
    if T % G != 0 or T // G < 8:   # tiny smoke inputs: fall back to global
        G = 1
    Tg = T // G
    xf = x.reshape(T, d)
    C = capacity if capacity is not None else max(
        8, int(Tg * K / E * cfg.moe_capacity))
    C = min(C, Tg)

    logits = (xf @ params["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gate_vals, eidx = jax.lax.top_k(probs, K)                  # [T, K]
    gates = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    f = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, E), axis=1), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p)

    # choice-major flattening per group: first choices claim capacity first
    eidx_g = eidx.reshape(G, Tg, K)
    gates_g = gates.reshape(G, Tg, K)
    e_flat = eidx_g.transpose(0, 2, 1).reshape(G, K * Tg)      # [G, K*Tg]
    tok_flat = jnp.tile(jnp.arange(Tg, dtype=jnp.int32), K)[None, :] \
        + (jnp.arange(G, dtype=jnp.int32) * Tg)[:, None]       # global ids
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)            # [G, K*Tg, E]
    pos = jnp.cumsum(oh, axis=1) - 1                           # local count
    pos_in_e = jnp.sum(pos * oh, axis=-1)                      # [G, K*Tg]
    keep = pos_in_e < C
    # expert-major slots: expert e owns rows [e*G*C, (e+1)*G*C)
    slot = jnp.where(keep,
                     e_flat * G * C + jnp.arange(G, dtype=jnp.int32)[:, None]
                     * C + pos_in_e,
                     E * G * C)                                # OOB => drop
    tok_flat = tok_flat.reshape(-1)
    keep, slot = keep.reshape(-1), slot.reshape(-1)
    C = G * C                                                  # per-expert

    from repro.parallel.sharding import constrain
    buf = jnp.zeros((E * C, d), xf.dtype).at[slot].add(
        xf[tok_flat], mode="drop").reshape(E, C, d)
    buf = constrain(buf, "expert", "moe_cap", None)  # a2a/EP boundary

    # expert FFN (swiglu), batched over E
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["we_g"].astype(xf.dtype))) * \
        jnp.einsum("ecd,edf->ecf", buf, params["we_i"].astype(xf.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, params["we_o"].astype(xf.dtype))
    out_flat = out_e.reshape(E * C, d)

    # combine by inverse-permutation GATHER (token-sharded, bf16) — a
    # scatter-add here materializes [K*T, d] f32 replicated and all-reduces
    # it (§Perf cell B, hypothesis confirmed: 7.7 TB/chip of AR wire).
    slot_tk = slot.reshape(G, K, Tg).transpose(0, 2, 1).reshape(T, K)
    keep_tk = keep.reshape(G, K, Tg).transpose(0, 2, 1).reshape(T, K)
    gathered = out_flat[jnp.minimum(slot_tk, E * C - 1)]       # [T, K, d]
    y = jnp.sum(jnp.where(keep_tk[:, :, None], gathered, 0)
                * gates.astype(gathered.dtype)[:, :, None], axis=1)
    from repro.parallel.sharding import constrain as _c
    y = _c(y, "fsdp", None)

    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], xf, "swiglu")
    return y.reshape(B, S, d), aux


def moe_block_dense_ref(params, cfg, x):
    """Oracle: loop over experts densely (no capacity drops).  Used by tests
    to validate dispatch within the no-drop regime."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(-1, d)
    logits = (xf @ params["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)
    gates = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    y = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(E):
        pe = {"wi": params["we_i"][e], "wg": params["we_g"][e],
              "wo": params["we_o"][e]}
        oe = mlp(pe, xf, "swiglu").astype(jnp.float32)
        w = jnp.sum(jnp.where(eidx == e, gates, 0.0), axis=-1)
        y = y + oe * w[:, None]
    y = y.astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], xf, "swiglu")
    return y.reshape(B, S, d)
