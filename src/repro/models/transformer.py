"""Family assembly: dense / MoE / SSM / hybrid / audio / VLM models.

Layers are scanned with stacked parameters (one compiled block regardless of
depth — essential for dry-run compile times at 95 layers) with per-layer
scalars (attention window) fed as scan inputs; heterogeneous-cache decode
(gemma2's alternating local/global, hymba's 3 global layers) unrolls the
layer loop so each layer binds its cache group statically, with ring buffers
for sliding-window layers.

Modes: ``train`` (loss-ready logits), ``prefill`` (build decode cache),
``decode`` (one token against the cache).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (PSpec, cross_entropy, embed, embed_specs, mlp,
                     mlp_specs, norm, norm_spec, stack_layers, unembed)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
def _block_specs(cfg, dense_ff: int | None = None):
    s = {"ln1": norm_spec(cfg)}
    if cfg.has_attn:
        s["attn"] = (mla_mod.mla_specs(cfg) if cfg.use_mla
                     else attn_mod.attn_specs(cfg))
    if cfg.has_ssm:
        s["ssm"] = ssm_mod.ssm_specs(cfg)
    ff = dense_ff if dense_ff is not None else cfg.d_ff
    if cfg.n_experts and dense_ff is None:
        s["ln2"] = norm_spec(cfg)
        s["moe"] = moe_mod.moe_specs(cfg)
    elif ff:
        s["ln2"] = norm_spec(cfg)
        s["mlp"] = mlp_specs(cfg.d_model, ff, cfg.act)
    return s


def model_specs(cfg):
    n_scanned = cfg.n_layers - (1 if cfg.first_dense_d_ff else 0)
    s = {
        "embed": embed_specs(cfg),
        "layers": stack_layers(lambda: _block_specs(cfg), n_scanned),
        "final_norm": norm_spec(cfg),
    }
    if cfg.first_dense_d_ff:
        s["layer0"] = _block_specs(cfg, dense_ff=cfg.first_dense_d_ff)
    return s


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------
def _layer(cfg, p, x, q_pos, window, cache, cache_len, mode,
           dense_ff: int | None = None):
    """Returns (x, new_cache_slice, aux)."""
    h = norm(cfg, x, p["ln1"])
    new_cache = {}
    parts = []
    if cfg.has_attn:
        if cfg.use_mla:
            out, nc = mla_mod.mla_block(
                p["attn"], cfg, h, q_pos,
                cache=None if cache is None else (cache["ckv"], cache["kr"]),
                cache_len=cache_len, window=0)
            if nc is not None:
                new_cache["ckv"], new_cache["kr"] = nc
            elif mode == "prefill":
                c, kr = mla_mod._project_latent(p["attn"], cfg, h, q_pos)
                new_cache["ckv"], new_cache["kr"] = c, kr
        else:
            out, nc = attn_mod.attention_block(
                p["attn"], cfg, h, q_pos, window=window,
                cache=None if cache is None else (cache["k"], cache["v"]),
                cache_len=cache_len)
            if nc is not None:
                new_cache["k"], new_cache["v"] = nc
            elif mode == "prefill":
                # stash this layer's K/V (recomputed: cheap vs attention)
                k = jnp.einsum("bsd,dke->bske", h,
                               p["attn"]["wk"].astype(h.dtype))
                v = jnp.einsum("bsd,dke->bske", h,
                               p["attn"]["wv"].astype(h.dtype))
                k = attn_mod.rope(k, q_pos, cfg.rope_theta)
                new_cache["k"], new_cache["v"] = k, v
        parts.append(out)
    if cfg.has_ssm:
        sc = None
        if cache is not None:
            sc = (cache["conv"], cache["ssm"])
        elif mode == "prefill":
            sc = "init"
        out2, nc2 = ssm_mod.ssm_block(p["ssm"], cfg, h, cache=sc)
        if nc2 is not None:
            new_cache["conv"], new_cache["ssm"] = nc2
        parts.append(out2)
    mix = parts[0] if len(parts) == 1 else \
        0.5 * (parts[0] + parts[1])          # hymba: parallel heads, averaged
    x = x + mix
    aux = jnp.float32(0.0)
    if "moe" in p and dense_ff is None:
        h2 = norm(cfg, x, p["ln2"])
        y, aux = moe_mod.moe_block(p["moe"], cfg, h2)
        x = x + y
    elif "mlp" in p:
        h2 = norm(cfg, x, p["ln2"])
        x = x + mlp(p["mlp"], h2, cfg.act)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# embedding of heterogeneous inputs
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg, batch):
    """-> (x [B,S,d], positions [B,S], label_mask [B,S] or None)."""
    if cfg.frontend == "audio":
        feats = batch["features"]
        if "mask" in batch:  # HuBERT-style masked prediction
            feats = feats * (1.0 - batch["mask"][..., None])
        x = feats.astype(jnp.bfloat16) @ \
            params["embed"]["frontend_proj"].astype(jnp.bfloat16)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return x, pos, batch.get("mask")
    if cfg.frontend == "vision":
        tok = embed(params["embed"], cfg, batch["tokens"])
        vis = batch["vision"].astype(tok.dtype)
        x = jnp.concatenate([vis, tok], axis=1)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mask = jnp.concatenate(
            [jnp.zeros(vis.shape[:2], jnp.float32),
             jnp.ones(tok.shape[:2], jnp.float32)], axis=1)
        return x, pos, mask
    x = embed(params["embed"], cfg, batch["tokens"])
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, pos, None


# ---------------------------------------------------------------------------
# forward (train / prefill) via layer scan
# ---------------------------------------------------------------------------
def forward(params, cfg, batch, mode: str = "train", cache=None,
            positions=None, cache_len=None):
    """Scanned forward pass.

    train:   batch -> logits [B,S,Vp], aux
    prefill: batch -> logits, cache (stacked [L,...]), aux
    decode:  batch['tokens'] [B,1] + cache + positions [B,1] -> logits, cache
    """
    assert mode in ("train", "prefill", "decode")
    if mode == "decode":
        x = embed(params["embed"], cfg, batch["tokens"])
        q_pos = positions
    else:
        x, q_pos, _ = embed_inputs(params, cfg, batch)

    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    n_scanned = cfg.n_layers - (1 if cfg.first_dense_d_ff else 0)
    if cfg.first_dense_d_ff:
        windows0, windows = windows[0], windows[1:]
        c0 = None if cache is None else jax.tree.map(lambda a: a[0], cache)
        x, nc0, _ = _layer(cfg, params["layer0"], x, q_pos, windows0, c0,
                           cache_len, mode, dense_ff=cfg.first_dense_d_ff)
    else:
        nc0 = None

    layer = partial(_layer, cfg)
    if cfg.remat != "none" and mode == "train":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        layer = jax.checkpoint(layer, policy=policy, static_argnums=(6,))

    def scan_body(x, inp):
        p, w, c = inp
        x = constrain(x, "fsdp", None, None)
        x, nc, aux = layer(p, x, q_pos, w, c, cache_len, mode)
        return x, (nc, aux)

    cache_scanned = None
    if cache is not None:
        cache_scanned = cache if not cfg.first_dense_d_ff else \
            jax.tree.map(lambda a: a[1:], cache)
    if cache_scanned is None:
        x, (ncs, auxs) = jax.lax.scan(
            lambda xc, inp: scan_body(xc, (inp[0], inp[1], None)),
            x, (params["layers"], windows))
    else:
        x, (ncs, auxs) = jax.lax.scan(
            scan_body, x, (params["layers"], windows, cache_scanned))

    x = norm(cfg, x, params["final_norm"])
    logits = unembed(params["embed"], cfg, x)
    logits = constrain(logits, "fsdp", None, "tensor")
    aux = jnp.sum(auxs) / max(n_scanned, 1)

    new_cache = None
    if mode in ("prefill", "decode") and ncs:
        new_cache = ncs
        if nc0 is not None:
            new_cache = jax.tree.map(
                lambda a0, rest: jnp.concatenate([a0[None], rest], axis=0),
                nc0, ncs)
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def needs_unrolled_decode(cfg, S_max: int) -> bool:
    """Heterogeneous cache shapes (ring vs full) => unroll the layer loop."""
    ws = cfg.layer_windows()
    kinds = {("ring" if 0 < w < S_max else "full") for w in ws
             if cfg.has_attn}
    return len(kinds) > 1


def init_cache(cfg, B: int, S_max: int, dtype=jnp.bfloat16):
    """Decode cache for the *scanned* (uniform) path, stacked [L, ...]."""
    L = cfg.n_layers
    c = {}
    if cfg.has_attn:
        if cfg.use_mla:
            c["ckv"] = jnp.zeros((L, B, S_max, cfg.kv_lora), dtype)
            c["kr"] = jnp.zeros((L, B, S_max, cfg.qk_rope_dim), dtype)
        else:
            kvh, hd = cfg.n_kv_heads, cfg.head_dim
            c["k"] = jnp.zeros((L, B, S_max, kvh, hd), dtype)
            c["v"] = jnp.zeros((L, B, S_max, kvh, hd), dtype)
    if cfg.has_ssm:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        c["conv"] = jnp.zeros((L, B, cfg.conv_kernel - 1, conv_dim), dtype)
        c["ssm"] = jnp.zeros((L, B, cfg.n_ssm_heads, cfg.ssm_headdim,
                              cfg.ssm_state), jnp.float32)
    return c


def init_cache_unrolled(cfg, B: int, S_max: int, dtype=jnp.bfloat16):
    """Heterogeneous cache: ring buffers for SWA layers, full for global."""
    ws = cfg.layer_windows()
    c = {"layers": []}
    for w in ws:
        lc = {}
        if cfg.has_attn:
            kvh, hd = cfg.n_kv_heads, cfg.head_dim
            S = min(w, S_max) if 0 < w < S_max else S_max
            lc["k"] = jnp.zeros((B, S, kvh, hd), dtype)
            lc["v"] = jnp.zeros((B, S, kvh, hd), dtype)
            lc["pos"] = jnp.full((B, S), -1, jnp.int32)  # absolute positions
        if cfg.has_ssm:
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            lc["conv"] = jnp.zeros((B, cfg.conv_kernel - 1, conv_dim), dtype)
            lc["ssm"] = jnp.zeros((B, cfg.n_ssm_heads, cfg.ssm_headdim,
                                   cfg.ssm_state), jnp.float32)
        c["layers"].append(lc)
    return c


def decode_unrolled(params, cfg, tokens, cache, positions):
    """One decode step with per-layer static cache groups (ring or full)."""
    x = embed(params["embed"], cfg, tokens)
    B = x.shape[0]
    ws = cfg.layer_windows()
    new_layers = []
    n0 = 1 if cfg.first_dense_d_ff else 0
    for li in range(cfg.n_layers):
        if li == 0 and n0:
            p = params["layer0"]
        else:
            p = jax.tree.map(lambda a: a[li - n0], params["layers"])
        lc = cache["layers"][li]
        nlc = dict(lc)
        h = norm(cfg, x, p["ln1"])
        parts = []
        if cfg.has_attn:
            w = ws[li]
            q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"].astype(h.dtype))
            k = jnp.einsum("bsd,dke->bske", h, p["attn"]["wk"].astype(h.dtype))
            v = jnp.einsum("bsd,dke->bske", h, p["attn"]["wv"].astype(h.dtype))
            q = attn_mod.rope(q, positions, cfg.rope_theta)
            k = attn_mod.rope(k, positions, cfg.rope_theta)
            S = lc["k"].shape[1]
            slot = (positions % S).astype(jnp.int32)          # ring write
            b = jnp.arange(B, dtype=jnp.int32)[:, None]
            kk = lc["k"].at[b, slot].set(k.astype(lc["k"].dtype))
            vv = lc["v"].at[b, slot].set(v.astype(lc["v"].dtype))
            pp = lc["pos"].at[b, slot].set(positions.astype(jnp.int32))
            nlc.update(k=kk, v=vv, pos=pp)
            out = attn_mod.blockwise_attention(
                q, kk, vv, positions, pp, causal=cfg.causal, window=w,
                cap=cfg.attn_softcap)
            out = jnp.einsum("bshe,hed->bsd", out,
                             p["attn"]["wo"].astype(h.dtype))
            parts.append(out)
        if cfg.has_ssm:
            out2, (cs, hs) = ssm_mod.ssm_block(
                p["ssm"], cfg, h, cache=(lc["conv"], lc["ssm"]))
            nlc.update(conv=cs, ssm=hs)
            parts.append(out2)
        x = x + (parts[0] if len(parts) == 1 else 0.5 * (parts[0] + parts[1]))
        if "moe" in p:
            y, _ = moe_mod.moe_block(p["moe"], cfg, norm(cfg, x, p["ln2"]))
            x = x + y
        elif "mlp" in p:
            x = x + mlp(p["mlp"], norm(cfg, x, p["ln2"]), cfg.act)
        new_layers.append(nlc)
    x = norm(cfg, x, params["final_norm"])
    logits = unembed(params["embed"], cfg, x)
    return logits, {"layers": new_layers}


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def train_loss(params, cfg, batch, aux_coef: float = 0.01,
               z_loss: float = 1e-4):
    logits, _, aux = forward(params, cfg, batch, mode="train")
    if cfg.frontend == "audio":
        labels = batch["labels"]
        mask = batch.get("mask")
        loss = cross_entropy(logits, labels, mask=mask, z_loss=z_loss)
    elif cfg.frontend == "vision":
        nv = batch["vision"].shape[1]
        lm_logits = logits[:, nv:-1]
        labels = batch["tokens"][:, 1:]
        loss = cross_entropy(lm_logits, labels, z_loss=z_loss)
    else:
        loss = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:],
                             z_loss=z_loss)
    return loss + aux_coef * aux
