"""Fault-tolerant checkpointing.

* flat path-keyed .npz shards + JSON manifest, written atomically
  (tmp-dir + rename) so a killed save never corrupts the latest checkpoint;
* async save (background thread) so the train loop never blocks on I/O;
* keep-last-k garbage collection;
* **elastic restore**: checkpoints store logical arrays, not device
  layouts — restore takes target shardings for whatever mesh the job
  restarts on (different pod count included) and `device_put`s each leaf.

At 1000+ nodes each host would write only its owned shard slices (the
manifest already records per-leaf shapes to support that); in this
single-process container the full arrays are written by rank 0.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{_SEP}{k}" if prefix
                                else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}"))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(flat, template):
    def rec(t, prefix):
        if isinstance(t, dict):
            return {k: rec(t[k], f"{prefix}{_SEP}{k}" if prefix else str(k))
                    for k in t}
        if isinstance(t, (list, tuple)):
            vals = [rec(v, f"{prefix}{_SEP}{i}") for i, v in enumerate(t)]
            return type(t)(vals)
        return flat[prefix]
    return rec(template, "")


def _to_numpy(v):
    """npz-safe array: bf16 (or other non-native dtypes) stored as f32
    exactly; the manifest records the logical dtype."""
    a = np.asarray(v)
    if a.dtype.kind not in "biufc":       # bfloat16 & friends (ml_dtypes)
        return a.astype(np.float32), str(a.dtype)
    return a, str(a.dtype)


def save_checkpoint(path: str, tree, step: int, extra: dict | None = None):
    """Atomic checkpoint write: <path>/step_<n>/{manifest.json, arrays.npz}"""
    pairs = {k: _to_numpy(v) for k, v in _flatten(tree).items()}
    flat = {k: p[0] for k, p in pairs.items()}
    logical = {k: p[1] for k, p in pairs.items()}
    final = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_save_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        manifest = {
            "step": step, "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": logical[k]}
                       for k, v in flat.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def restore_checkpoint(path: str, template, step: int | None = None,
                       shardings=None):
    """Restore into ``template``'s structure; reshard onto ``shardings``
    (a matching tree of NamedShardings) if given — elastic restarts."""
    steps = list_steps(path)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {path}")
    step = steps[-1] if step is None else step
    d = os.path.join(path, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    tmpl_flat = _flatten(template)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {}
        for k in z.files:
            v = z[k]
            logical = manifest["leaves"][k]["dtype"]
            if str(v.dtype) != logical:   # bf16 stored as f32
                v = jax.numpy.asarray(v).astype(logical)
            # align to the template dtype while still in numpy: a
            # jnp.asarray on an int64/float64 leaf with x64 disabled
            # silently truncates the *values* — no later astype can
            # recover them — so dtype fixup must precede any jnp hop
            want = getattr(tmpl_flat.get(k), "dtype", None)
            if want is not None and v.dtype != want:
                v = v.astype(want)
            flat[k] = v
    if shardings is not None:
        sh_flat = _flatten(shardings)
        flat = {k: jax.device_put(v, sh_flat[k]) for k, v in flat.items()}
    else:
        # device arrays for every dtype jax can represent exactly; a
        # 64-bit leaf under disabled x64 stays a host numpy array (the
        # exact values) instead of a corrupted device array
        flat = {k: (v if (getattr(v, "dtype", None) is not None
                          and jax.dtypes.canonicalize_dtype(v.dtype)
                          != v.dtype)
                    else jax.numpy.asarray(v))
                for k, v in flat.items()}
    return _unflatten_into(flat, template), manifest


def list_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for d in os.listdir(path):
        if d.startswith("step_"):
            out.append(int(d.split("_")[1]))
    return sorted(out)


class CheckpointManager:
    """Async save + keep-k GC + latest-step tracking."""

    def __init__(self, path: str, keep: int = 3, async_save: bool = True):
        self.path = path
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(path, exist_ok=True)

    def save(self, tree, step: int, extra: dict | None = None):
        self.wait()
        # materialize on host BEFORE backgrounding (donated buffers!)
        flat_host = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.path, flat_host, step, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self.check()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.check()

    def check(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore(self, template, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.path, template, step, shardings)

    def latest_step(self):
        s = list_steps(self.path)
        return s[-1] if s else None

    def _gc(self):
        steps = list_steps(self.path)
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)
