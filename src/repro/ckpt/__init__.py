from .checkpoint import (CheckpointManager, restore_checkpoint,  # noqa
                         save_checkpoint)
