from .checkpoint import (CheckpointManager, list_steps,  # noqa
                         restore_checkpoint, save_checkpoint)
