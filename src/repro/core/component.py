"""Component kinds (Akita §3.1 'Component' + §3.2 'TickingComponent').

A *kind* is a class of components (cores, caches, DRAM controllers...); the
*instances* of a kind are rows of a batched state pytree and are executed with
``vmap`` — the SPMD analogue of Akita running many component objects.

The developer-facing contract is Akita's: implement one ``tick_fn`` that takes
the instance state, its :class:`~repro.core.ports.Ports` view and the current
virtual time, and returns the new state, new ports and whether the tick made
*forward progress*.  Everything else — sleeping, wakeups, scheduling, parallel
execution — is the engine's job (paper Fig. 3).

``tick_fn(state, ports, t) -> (state, ports, progress)`` or
``tick_fn(state, ports, t) -> (state, ports, TickResult(progress, next_time))``

A kind may additionally opt in to *traced model parameters* by declaring a
``params`` pytree: its ``tick_fn`` then takes a 4th argument —
``tick_fn(state, ports, t, params)`` — holding that pytree (shared by all
instances of the kind, i.e. broadcast under the instance vmap).  Declared
defaults are baked into ``Simulation.default_params()`` and can be
overridden per ``run()`` — or batched over by ``repro.dse`` — without
rebuilding or recompiling (see DSE.md).

``next_time`` (optional, -1 = unset) requests a wake at an arbitrary future
virtual time — this is the pure event-driven escape hatch (used by TrioSim to
fast-forward over operator execution) that Smart Ticking layers on top of.

Contract required for exact smart==naive equivalence (and honored by all
first-party components): a tick that returns ``progress=False`` must leave the
instance state and ports unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TickResult:
    progress: jax.Array                 # bool scalar
    next_time: jax.Array | None = None  # f32 scalar, <0 = default scheduling

    @staticmethod
    def make(progress, next_time=None):
        nt = jnp.asarray(-1.0 if next_time is None else next_time, jnp.float32)
        return TickResult(jnp.asarray(progress, bool), nt)


def normalize_tick_output(out) -> tuple[Any, Any, TickResult]:
    state, ports, res = out
    if not isinstance(res, TickResult):
        res = TickResult.make(res)
    elif res.next_time is None:
        res = TickResult.make(res.progress)
    return state, ports, res


@dataclasses.dataclass
class ComponentKind:
    """Static description of one component kind."""

    name: str
    tick_fn: Callable
    n_instances: int
    n_ports: int
    init_state: Any                      # pytree, leaves [N, ...]
    period: float | Any = 1.0            # scalar or [N] — cycle length
    cap: int | Any = 4                   # scalar, [P], or [N, P] buffer capacity
    start_asleep: bool = False           # if True, wait for a message to start
    params: Any = None                   # opt-in traced model params pytree;
    #                                      non-None => tick_fn is 4-ary

    @property
    def n_ports_total(self) -> int:
        """Size of this kind's port-state segment (``n_instances*n_ports``,
        instance-major) — see the engine's segmented ``SimState`` layout."""
        return self.n_instances * self.n_ports

    def periods(self):
        p = np.asarray(self.period, np.float32)
        if p.ndim == 0:
            p = np.full((self.n_instances,), float(p), np.float32)
        assert p.shape == (self.n_instances,)
        return p

    def caps(self):
        c = np.asarray(self.cap, np.int32)
        if c.ndim == 0:
            c = np.full((self.n_instances, self.n_ports), int(c), np.int32)
        elif c.ndim == 1:
            c = np.broadcast_to(c[None, :], (self.n_instances, self.n_ports)).copy()
        assert c.shape == (self.n_instances, self.n_ports)
        return c


@dataclasses.dataclass(frozen=True)
class KindHandle:
    """Returned by ``SimBuilder.add_kind``; names ports for ``connect``."""

    name: str
    index: int

    def port(self, instance: int, port: int = 0):
        return (self.name, instance, port)
