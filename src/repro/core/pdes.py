"""Sharded conservative PDES (paper §3.3, scaled out).

Akita parallelizes by triggering same-timestamp events on multiple CPU cores.
The JAX-native scale-out analogue: shard the *component axis* over devices
with ``shard_map``.  Each shard owns a replica of the shard-local topology
(SPMD — same compiled program, different component data) plus one ``_remote``
gateway kind whose ports are cross-shard channels.

Conservative synchronization (Fujimoto [16]; null-message-free because the
lookahead is static): all shards agree on the global next event time with
``pmin``, then each runs a *window* of ``lookahead`` cycles locally — any
message emitted inside the window arrives at its destination shard no earlier
than the window boundary plus the transport latency, so no shard can receive
a straggler event in its past.  Cross-shard messages ride fixed-capacity
mailboxes exchanged with ``all_to_all`` at window boundaries (a flow-style
network phase, the same abstraction TrioSim uses for data movement).

Component code is untouched — the same single-instance ``tick_fn`` written
for the single-device engine runs here, which is precisely the paper's
"transparent parallel simulation" claim (DX-3).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

try:                        # jax >= 0.5 exports it at top level
    shard_map_compat = jax.shard_map
except AttributeError:      # pragma: no cover
    from jax.experimental.shard_map import shard_map as shard_map_compat

# replication-check kwarg was renamed check_rep -> check_vma across versions
import inspect as _inspect

_SM_KW: dict = {}
for _kw in ("check_vma", "check_rep"):
    if _kw in _inspect.signature(shard_map_compat).parameters:
        _SM_KW = {_kw: False}
        break

from .component import ComponentKind, TickResult
from .engine import INF, SimBuilder, Simulation, _align_after
from .message import MSG_WORDS, W_DST, W_TIME, f2i
from .ports import EPS

REMOTE_KIND = "_remote"

LANE_AXIS = "lanes"          # the batched-DSE mesh axis (config lanes)

_MESHES: dict[tuple[int, str], Mesh] = {}


def lane_mesh(n_devices: int | None = None, axis: str = LANE_AXIS) -> Mesh:
    """A cached 1-D device mesh over the first ``n_devices`` local
    devices (all of them by default).

    This is the shared mesh machinery for every ``shard_map`` user in
    the repo: the PDES component-axis shards (:class:`ShardedSim`) and
    the DSE config-axis shards (``repro.dse`` sharded sweep rounds) both
    draw their meshes here, so one process holds exactly one ``Mesh``
    object per (device count, axis name) — meshes are part of jit cache
    keys, and a fresh ``Mesh`` per call would defeat executable reuse.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else max(1, min(int(n_devices),
                                                       len(devs)))
    key = (n, axis)
    m = _MESHES.get(key)
    if m is None:
        m = _MESHES[key] = Mesh(np.array(devs[:n]), (axis,))
    return m


def _gateway_tick(state, ports, t):
    # The gateway never ticks; the PDES wrapper moves its buffers directly.
    return state, ports, TickResult.make(jnp.asarray(False))


def add_gateway(builder: SimBuilder, n_peers: int, chan_per_peer: int,
                cap: int = 8) -> "object":
    """Add the cross-shard gateway kind to a shard-local topology.

    Port layout: ``port[p * 2*chan_per_peer + 2*c]`` is the *egress* channel c
    toward peer-shard-offset p (connect local senders to it), and
    ``...+ 2*c + 1`` is the matching *ingress* channel (connect it to local
    receivers).  Peer offset p means "shard (me + 1 + p) % D".
    """
    n_ports = n_peers * chan_per_peer * 2
    kind = ComponentKind(
        REMOTE_KIND, _gateway_tick, n_instances=1, n_ports=n_ports,
        init_state={"_": jnp.zeros((1,), jnp.int32)}, cap=cap,
        start_asleep=True)
    return builder.add_kind(kind)


class ShardedSim:
    """Runs one shard-local ``Simulation`` per device, conservatively synced.

    ``build_fn() -> (SimBuilder, gateway_handle)`` must register the gateway
    via :func:`add_gateway`.  All shards share the topology (SPMD); per-shard
    state is set by editing the stacked init state.
    """

    def __init__(self, build_fn, n_shards: int, n_peers: int,
                 chan_per_peer: int, mesh: Mesh | None = None,
                 axis: str = "sim", lookahead: float = 8.0,
                 mailbox: int = 8):
        builder, _ = build_fn()
        self.sim = builder.build()
        self.n_shards = n_shards
        self.n_peers, self.chan = n_peers, chan_per_peer
        self.lookahead = float(lookahead)
        self.mailbox = int(mailbox)
        self.axis = axis
        if mesh is None:
            mesh = lane_mesh(1, axis)
        self.mesh = mesh
        ki = [i for i, k in enumerate(self.sim.kinds)
              if k.name == REMOTE_KIND]
        assert ki, "topology must include the gateway (add_gateway)"
        self.gw_port_base = self.sim.port_base[ki[0]]
        assert self.sim.kinds[ki[0]].caps().max() <= self.mailbox, \
            "mailbox must cover gateway buffer capacity"

    # ------------------------------------------------------------------
    def init_state(self):
        """Stacked state [D, ...] for all shards, sharded over the mesh."""
        s0 = self.sim.init_state()
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.n_shards,) + a.shape),
            s0)
        return stacked

    def shard_state(self, stacked):
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.tree.map(
            lambda a: jax.device_put(
                a, NamedSharding(self.mesh,
                                 P(*([self.axis] + [None] * (a.ndim - 1))))),
            stacked)

    # ------------------------------------------------------------------
    def _exchange(self, s, t_end):
        """Drain gateway egress -> all_to_all -> inject gateway ingress.

        With the segmented port-state layout the gateway's buffers are its
        own kind segment, so draining/injecting touches only that segment
        (no full-array scatters)."""
        sim = self.sim
        npr, ch, mb = self.n_peers, self.chan, self.mailbox
        cap = sim.cap_phys
        RK = REMOTE_KIND

        # --- drain egress in-buffers (ports 2k) into mailbox [P, C, MB, W]
        eg = np.arange(npr * ch, dtype=np.int32) * 2   # gateway-local ids
        heads, cnts = s.in_head[RK][eg], s.in_cnt[RK][eg]         # [P*C]
        idx = (heads[:, None] + jnp.arange(mb, dtype=jnp.int32)[None, :]) % cap
        msgs = s.in_buf[RK][eg[:, None], idx]                     # [P*C,MB,W]
        vmask = jnp.arange(mb)[None, :] < cnts[:, None]
        msgs = jnp.where(vmask[:, :, None], msgs, 0)
        out_mail = msgs.reshape(npr, ch, mb, MSG_WORDS)
        s = dataclasses.replace(
            s,
            in_cnt={**s.in_cnt, RK: s.in_cnt[RK].at[eg].set(0)},
            in_head={**s.in_head, RK: s.in_head[RK].at[eg].set(0)})

        # --- transport: rotate-by-offset exchange over the shard axis.
        # Peer offset p on shard i targets shard (i+1+p) % D; ppermute each
        # offset's slice (a deterministic torus schedule; for D tested up to
        # 512 via the dry-run).
        D = self.n_shards
        if D > 1:
            slabs = []
            for p in range(npr):
                perm = [(i, (i + 1 + p) % D) for i in range(D)]
                slabs.append(jax.lax.ppermute(out_mail[p], self.axis, perm))
            in_mail = jnp.stack(slabs)            # [P, C, MB, W] from peers
        else:
            in_mail = out_mail

        # --- inject into gateway ingress out-buffers (ports 2k+1)
        ing = np.arange(npr * ch, dtype=np.int32) * 2 + 1  # gateway-local
        ing_g = self.gw_port_base + ing                    # global ids
        flat = in_mail.reshape(npr * ch, mb, MSG_WORDS)
        valid = flat[:, :, 0] != 0                                 # opcode!=0
        n_new = jnp.sum(valid, axis=1).astype(jnp.int32)
        # compact valid messages to the front of each channel
        order = jnp.argsort(~valid, axis=1, stable=True)
        flat = jnp.take_along_axis(flat, order[:, :, None], axis=1)
        # rewrite dst to the ingress port's local peer; stamp ready time
        peer = sim.c["peer"][ing_g]                                # [P*C]
        flat = flat.at[:, :, W_DST].set(
            jnp.broadcast_to(peer[:, None], flat.shape[:2]))
        flat = flat.at[:, :, W_TIME].set(f2i(jnp.full(flat.shape[:2],
                                                      t_end, jnp.float32)))
        pad = jnp.zeros((npr * ch, cap - mb, MSG_WORDS), jnp.int32) \
            if cap > mb else None
        stock = jnp.concatenate([flat[:, :cap], pad], axis=1) if pad is not None \
            else flat[:, :cap]
        s = dataclasses.replace(
            s,
            out_buf={**s.out_buf, RK: s.out_buf[RK].at[ing].set(stock)},
            out_head={**s.out_head, RK: s.out_head[RK].at[ing].set(0)},
            out_cnt={**s.out_cnt,
                     RK: s.out_cnt[RK].at[ing].set(jnp.minimum(n_new, cap))})
        # wake the serving connections so the crossbar forwards them
        conns = sim.c["port_conn"][ing_g]
        has = n_new > 0
        cw = s.conn_wake.at[jnp.where(has, conns, sim.n_conn)].min(
            _align_after(t_end, 1.0), mode="drop")
        return dataclasses.replace(s, conn_wake=cw)

    # ------------------------------------------------------------------
    def _local_next(self, s):
        return jnp.minimum(jnp.min(s.next_tick), jnp.min(s.conn_wake))

    def _step_window(self, s, horizon):
        """One conservative window: sync time, run lookahead, exchange."""
        t_loc = self._local_next(s)
        t_glob = jax.lax.pmin(t_loc, self.axis)
        t_end = jnp.minimum(t_glob + self.lookahead, horizon)
        s = self.sim._run(s, t_end - 2 * EPS, max_epochs=1_000_000)
        s = dataclasses.replace(s, time=jnp.maximum(s.time, t_end))
        s = self._exchange(s, t_end)
        return s

    def run(self, stacked_state, until: float, max_windows: int = 10_000,
            return_windows: bool = False):
        """Advance all shards to virtual time ``until``."""
        spec = lambda a: P(*([self.axis] + [None] * (a.ndim - 1)))
        in_specs = jax.tree.map(spec, stacked_state)

        @partial(shard_map_compat, mesh=self.mesh, in_specs=(in_specs,),
                 out_specs=(in_specs, P(self.axis)), **_SM_KW)
        def _run(st):
            s = jax.tree.map(lambda a: a[0], st)     # local shard

            def cond(carry):
                s, w = carry
                t = jax.lax.pmin(self._local_next(s), self.axis)
                return (t <= until + EPS) & (w < max_windows)

            def body(carry):
                s, w = carry
                return self._step_window(s, jnp.float32(until)), w + 1

            s, w = jax.lax.while_loop(cond, body, (s, jnp.int32(0)))
            return jax.tree.map(lambda a: a[None], s), w[None]

        out, w = _run(stacked_state)
        return (out, int(w[0])) if return_windows else out

    def lower(self, until: float = 1024.0):
        """AOT-lower ``run`` for the dry-run (no allocation)."""
        st = jax.eval_shape(self.init_state)
        fn = lambda s: self.run(s, until)
        return jax.jit(fn).lower(st)
