"""Daisen-lite: post-simulation trace visualization (paper §3.6).

Generates a single self-contained HTML file from a DBTracer's task table:
an Overview Panel (tasks-in-flight per location over time) plus a
per-location task timeline with parent-child drill-down on hover — the same
data model as Daisen (overview / component timelines / task hierarchy),
rendered offline with no external dependencies.
"""
from __future__ import annotations

import html
import json


def _embed_json(obj) -> str:
    """JSON for embedding inside a ``<script>`` block.

    A task whose ``category``/``action``/``location`` contains
    ``</script>`` (or any markup) would otherwise terminate the script
    element mid-JSON and break — or script-inject — the page.  Escaping
    ``<``, ``>`` and ``&`` to ``\\uXXXX`` keeps the payload valid JSON
    *and* inert HTML (the canonical safe-embedding trick).
    """
    return (json.dumps(obj)
            .replace("&", "\\u0026")
            .replace("<", "\\u003c")
            .replace(">", "\\u003e"))

_TEMPLATE = """<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>Daisen-lite trace</title>
<style>
 body{font-family:monospace;margin:12px;background:#fafafa}
 .lane{position:relative;height:22px;border-bottom:1px solid #eee}
 .lane .name{position:absolute;left:0;width:220px;overflow:hidden;
   font-size:11px;line-height:22px;color:#444}
 .lane .track{position:absolute;left:230px;right:0;top:2px;bottom:2px}
 .task{position:absolute;top:0;height:100%;border-radius:2px;opacity:.85;
   min-width:1px}
 .task:hover{outline:2px solid #000;z-index:5}
 #info{position:fixed;bottom:0;left:0;right:0;background:#222;color:#eee;
   padding:6px;font-size:12px;white-space:pre}
 h3{margin:6px 0}
</style></head><body>
<h3>Daisen-lite — __TITLE__</h3>
<div id="lanes"></div><div id="info">hover a task…</div>
<script>
const TASKS = __TASKS__;
const colors = {};
let ci = 0;
const palette = ['#4c78a8','#f58518','#54a24b','#e45756','#72b7b2',
                 '#b279a2','#ff9da6','#9d755d','#bab0ac','#eeca3b'];
function color(c){ if(!(c in colors)) colors[c]=palette[ci++%palette.length];
  return colors[c]; }
const t0 = Math.min(...TASKS.map(t=>t.start));
const t1 = Math.max(...TASKS.map(t=>t.end));
const span = Math.max(t1-t0, 1e-9);
const byLoc = {};
TASKS.forEach(t=>{(byLoc[t.location] ||= []).push(t);});
const byId = Object.fromEntries(TASKS.map(t=>[t.id,t]));
const lanes = document.getElementById('lanes');
Object.keys(byLoc).sort().forEach(loc=>{
  const lane = document.createElement('div'); lane.className='lane';
  lane.innerHTML = `<div class="name">${loc}</div><div class="track"></div>`;
  const track = lane.querySelector('.track');
  byLoc[loc].forEach(t=>{
    const d = document.createElement('div'); d.className='task';
    d.style.left = (100*(t.start-t0)/span)+'%';
    d.style.width = Math.max(100*(t.end-t.start)/span, .05)+'%';
    d.style.background = color(t.category);
    d.onmouseenter = ()=>{
      let chain=[], cur=t;
      while(cur){chain.unshift(`@${cur.location} ${cur.category}/${cur.action}`
        + ` [${cur.start.toFixed(3)},${cur.end.toFixed(3)}]`);
        cur = byId[cur.parent_id];}
      document.getElementById('info').textContent =
        chain.join('\\n') + '\\ntags: ' + JSON.stringify(t.tags);
    };
    track.appendChild(d);
  });
  lanes.appendChild(lane);
});
</script></body></html>
"""


def export_html(tasks, out_path: str, title: str = "simulation trace"):
    """Write a standalone HTML timeline for a list of completed Tasks."""
    rows = [dict(id=t.id, parent_id=t.parent_id, category=t.category,
                 action=t.action, location=t.location, start=t.start,
                 end=t.end if t.end is not None else t.start, tags=t.tags)
            for t in tasks]
    # positional substitution: sequential .replace() would let a task
    # string containing the literal placeholder text corrupt the page
    head, rest = _TEMPLATE.split("__TITLE__")
    mid, tail = rest.split("__TASKS__")
    doc = head + html.escape(title) + mid + _embed_json(rows) + tail
    with open(out_path, "w") as fh:
        fh.write(doc)
    return out_path


def export_db(db, out_path: str, title: str = "simulation trace"):
    return export_html(db.fetch_tasks(), out_path, title)
