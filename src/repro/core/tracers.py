"""First-party tracers (paper §3.4): TotalTime, AverageTime, BusyTime,
TagCount, and DBTracer (SQLite — the paper's default — and CSV).

Tracers receive task annotations and decide what to do with them; they can be
attached per-domain with a filter predicate (the analogue of attaching a
tracer to a subset of components).  A ``metrics()`` method returns the
collected summary, and DBTracer persists the complete task tree for
post-simulation analysis (Daisen export reads it back).
"""
from __future__ import annotations

import csv
import json
import sqlite3
import threading
from collections import defaultdict

from .tracing import Task


class _Base:
    def on_start(self, t: Task):
        pass

    def on_end(self, t: Task):
        pass

    def on_tag(self, t: Task, tag: str):
        pass


class TotalTimeTracer(_Base):
    """Total time spent in matching tasks (e.g. total memory latency)."""

    def __init__(self):
        self.total = 0.0
        self.count = 0

    def on_end(self, t: Task):
        if t.end is not None:
            self.total += t.end - t.start
            self.count += 1

    def metrics(self):
        return {"total_time": self.total, "count": self.count}


class AverageTimeTracer(TotalTimeTracer):
    """Average task latency (e.g. average L2 transaction latency)."""

    def metrics(self):
        avg = self.total / self.count if self.count else 0.0
        return {"avg_time": avg, "count": self.count}


class BusyTimeTracer(_Base):
    """Time a location is handling >=1 task (e.g. ALU utilization)."""

    def __init__(self):
        self.busy = defaultdict(float)
        self._active = defaultdict(int)
        self._since = {}

    def on_start(self, t: Task):
        loc = t.location
        if self._active[loc] == 0:
            self._since[loc] = t.start
        self._active[loc] += 1

    def on_end(self, t: Task):
        loc = t.location
        self._active[loc] -= 1
        if self._active[loc] == 0 and t.end is not None:
            self.busy[loc] += t.end - self._since.pop(loc)

    def metrics(self):
        return dict(self.busy)


class TagCountTracer(_Base):
    """Counts tag occurrences (e.g. cache hits vs misses)."""

    def __init__(self):
        self.counts = defaultdict(int)

    def on_tag(self, t: Task, tag: str):
        self.counts[tag] += 1

    def metrics(self):
        return dict(self.counts)


class DBTracer(_Base):
    """Persists every completed task (SQLite default, CSV alternative).

    The SQLite database also carries a ``runs`` table with execution info and
    a ``metrics`` table for periodic series (buffer levels, port throughput)
    — the paper's performance-analysis framework (§3.4).
    """

    SCHEMA = """
    CREATE TABLE IF NOT EXISTS runs(
        run_id TEXT PRIMARY KEY, command TEXT, workdir TEXT,
        start REAL, end REAL, info TEXT);
    CREATE TABLE IF NOT EXISTS tasks(
        id TEXT, parent_id TEXT, category TEXT, action TEXT, location TEXT,
        start REAL, end REAL, tags TEXT, details TEXT);
    CREATE TABLE IF NOT EXISTS metrics(
        run_id TEXT, name TEXT, location TEXT, t REAL, value REAL);
    """

    def __init__(self, path: str, backend: str = "sqlite",
                 run_id: str = "run0"):
        self.path, self.backend, self.run_id = str(path), backend, run_id
        self._lock = threading.Lock()
        if backend == "sqlite":
            self._conn = sqlite3.connect(self.path, check_same_thread=False)
            self._conn.executescript(self.SCHEMA)
            import os
            import sys
            self._conn.execute(
                "INSERT OR REPLACE INTO runs VALUES(?,?,?,?,?,?)",
                (run_id, " ".join(sys.argv), os.getcwd(), 0.0, -1.0, "{}"))
            self._conn.commit()
        elif backend == "csv":
            self._fh = open(self.path, "w", newline="")
            self._csv = csv.writer(self._fh)
            self._csv.writerow(Task.ROW_FIELDS)
        else:
            raise ValueError(backend)

    def on_end(self, t: Task):
        with self._lock:
            if self.backend == "sqlite":
                self._conn.execute(
                    "INSERT INTO tasks VALUES(?,?,?,?,?,?,?,?,?)", t.row())
            else:
                self._csv.writerow(t.row())

    def add_metric(self, name: str, location: str, t: float, value: float):
        if self.backend == "sqlite":
            with self._lock:
                self._conn.execute("INSERT INTO metrics VALUES(?,?,?,?,?)",
                                   (self.run_id, name, location, t, value))

    def add_metrics(self, rows):
        """rows: iterable of (name, location, t, value)."""
        if self.backend == "sqlite":
            with self._lock:
                self._conn.executemany(
                    "INSERT INTO metrics VALUES(?,?,?,?,?)",
                    [(self.run_id, *r) for r in rows])

    def flush(self):
        with self._lock:
            if self.backend == "sqlite":
                self._conn.commit()
            else:
                self._fh.flush()

    def close(self):
        self.flush()
        if self.backend == "sqlite":
            self._conn.close()
        else:
            self._fh.close()

    # -- read-back helpers (used by Daisen export + tests) ------------------
    def fetch_tasks(self):
        assert self.backend == "sqlite"
        cur = self._conn.execute("SELECT * FROM tasks ORDER BY start")
        out = []
        for row in cur.fetchall():
            out.append(Task(id=row[0], parent_id=row[1], category=row[2],
                            action=row[3], location=row[4], start=row[5],
                            end=None if row[6] < 0 else row[6],
                            tags=json.loads(row[7]),
                            details=json.loads(row[8])))
        return out

    def fetch_metrics(self, name: str | None = None):
        assert self.backend == "sqlite"
        q = "SELECT name, location, t, value FROM metrics"
        args = ()
        if name:
            q += " WHERE name=?"
            args = (name,)
        return self._conn.execute(q + " ORDER BY t", args).fetchall()


def flush_engine_trace(sim, state, db: DBTracer, virtual_time_scale=1.0):
    """Flush device-level engine counters into the trace DB (§3.4's periodic
    buffer-level / busy-time recording): per-component busy ticks and the
    sampled in-buffer levels."""
    import numpy as np
    busy = np.asarray(state.stats.busy)
    rows = []
    ci = 0
    for k in sim.kinds:
        for i in range(k.n_instances):
            rows.append(("busy_ticks", f"{k.name}[{i}]", float(state.time),
                         float(busy[ci])))
            ci += 1
    if sim.max_samples and int(state.sample_idx) > 0:
        samples = np.asarray(state.buf_samples)
        n = min(int(state.sample_idx), sim.max_samples)
        for si in range(n):
            t = (si + 1) * sim.sample_period * virtual_time_scale
            for ki, k in enumerate(sim.kinds):
                pb = sim.port_base[ki]
                for inst in range(k.n_instances):
                    for p in range(k.n_ports):
                        rows.append((
                            "buf_level", f"{k.name}[{inst}].p{p}", t,
                            float(samples[si, pb + inst * k.n_ports + p])))
    db.add_metrics(rows)
    db.flush()
