"""Fixed-width message records (Akita §3.1 'Message').

Messages are pure-data int32 records of ``MSG_WORDS`` words:

  w0  opcode      user-defined message/opcode id (0 is reserved: empty slot)
  w1  src port    global port id (filled by ``Ports.send``)
  w2  dst port    global port id (-1 = "use the port's default peer")
  w3  ready time  f32 virtual time, bitcast into i32 (stamped by the connection)
  w4..w7          payload words (user-defined; bitcast floats if needed)

The fixed width is the TPU-native analogue of Akita's typed Go message structs:
static shapes let buffers live in arrays and messages move as vector ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MSG_WORDS = 8
_PAYLOAD0 = 4
N_PAYLOAD = MSG_WORDS - _PAYLOAD0

# Word indices.
W_OP = 0
W_SRC = 1
W_DST = 2
W_TIME = 3


def f2i(x):
    """Bitcast float32 -> int32 (for storing times/floats in payload words)."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.int32)


def i2f(x):
    """Bitcast int32 -> float32."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.int32), jnp.float32)


def msg_new(opcode, dst=-1, p0=0, p1=0, p2=0, p3=0):
    """Build a message. ``dst`` < 0 means "send to the port's default peer"."""
    return jnp.stack([
        jnp.asarray(opcode, jnp.int32),
        jnp.asarray(-1, jnp.int32),
        jnp.asarray(dst, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(p0, jnp.int32),
        jnp.asarray(p1, jnp.int32),
        jnp.asarray(p2, jnp.int32),
        jnp.asarray(p3, jnp.int32),
    ])


def msg_reply(msg, opcode, p0=0, p1=0, p2=0, p3=0):
    """Build a reply addressed to the sender of ``msg``."""
    return msg_new(opcode, dst=msg[W_SRC], p0=p0, p1=p1, p2=p2, p3=p3)


def opcode(msg):
    return msg[..., W_OP]


def payload(msg, i):
    return msg[..., _PAYLOAD0 + i]


def ready_time(msg):
    return i2f(msg[..., W_TIME])
