"""Batched ring-buffer ports (Akita §3.1 'Port').

Each port owns an incoming and an outgoing FIFO ring buffer.  Globally, all
ports of all component instances live in flat arrays indexed by a *global port
id* so connections can deliver with pure scatter/gather ops.  A component's
``tick_fn`` sees only its own instance's slice through the :class:`Ports`
view, whose ``recv``/``send``/``peek`` mirror Akita's port API — functional
(they return a new view) but reading like cycle-based code.

Send rejects when the outgoing buffer is full (returns ``ok=False``) exactly
like Akita; the engine uses the resulting full/not-full transitions for Smart
Ticking rule 2 and Availability Backpropagation.

Globally, port state lives in *per-kind segments* of the engine's
``SimState`` (see ENGINE_PERF.md); a ``Ports`` view is one instance's window
into its kind's segment.  Ring-buffer reads/writes at the (dynamic) head and
tail positions are formulated as one-hot selects over the tiny ``CAP`` axis
rather than dynamic indexing: under ``vmap`` the latter lowers to XLA
gather/scatter, which on CPU costs two orders of magnitude more than the
equivalent masked arithmetic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .message import MSG_WORDS, W_DST, W_SRC, W_TIME, i2f

EPS = 1e-3


def oh_set(arr, ix, val, when=True):
    """Scatter-free ``arr.at[ix].set(val)`` for a *traced* index on a tiny
    leading axis: a one-hot compare over ``axis 0`` plus a masked select.

    ``x.at[traced_ix].set(v)`` lowers to an XLA scatter, which under the
    instance/config vmaps can survive into compiled code — and on CPU XLA a
    scatter costs ~100x the equivalent select at these sizes
    (ENGINE_PERF.md).  Component ``tick_fn``s should use this helper for
    dynamic single-row updates of small state tables (cache tag arrays,
    register scoreboards, ...); for in-range indices the values are
    bit-identical to ``.at[].set``.  Out-of-range indices are *dropped*
    (no row matches the one-hot), unlike ``.at[].set``'s clamp-and-write —
    which makes a past-the-end index a safe "no update" sentinel.

    ``when=False`` makes the call a no-op (keeps the progress=False
    "unchanged state" contract easy to honor).
    """
    oh = jnp.arange(arr.shape[0]) == ix
    oh = oh & jnp.asarray(when, bool)
    oh = oh.reshape((arr.shape[0],) + (1,) * (arr.ndim - 1))
    return jnp.where(oh, jnp.asarray(val, arr.dtype), arr)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Ports:
    """Per-instance view over this component's ports.

    Arrays are shaped ``[P, ...]`` where ``P`` is the number of ports the
    component kind declares.  ``t`` is the current virtual time (cycles).
    """

    in_buf: jax.Array   # [P, CAP, W] i32
    in_head: jax.Array  # [P] i32
    in_cnt: jax.Array   # [P] i32
    out_buf: jax.Array  # [P, CAP, W] i32
    out_head: jax.Array  # [P] i32
    out_cnt: jax.Array   # [P] i32
    cap: jax.Array      # [P] i32 logical capacity (<= physical CAP)
    gid: jax.Array      # [P] i32 global port ids
    peer: jax.Array     # [P] i32 default peer port id (-1 if ambiguous)
    t: jax.Array        # scalar f32

    @property
    def _cap_phys(self):
        return self.in_buf.shape[1]

    # -- incoming ---------------------------------------------------------
    def peek(self, p):
        """Return (msg, ok) for the head of port ``p``'s incoming buffer.

        ``ok`` is False when the buffer is empty or the head message has not
        yet arrived (its connection-stamped ready time is in the future).
        """
        row = self.in_buf[p]                            # [CAP, W]
        oh = self.in_head[p] == jnp.arange(self._cap_phys)
        msg = jnp.sum(row * oh[:, None].astype(row.dtype), axis=0)
        ok = (self.in_cnt[p] > 0) & (i2f(msg[W_TIME]) <= self.t + EPS)
        return msg, ok

    def recv(self, p, when=True):
        """Pop the head message of port ``p`` if present+ready and ``when``."""
        msg, ok = self.peek(p)
        ok = ok & jnp.asarray(when, bool)
        oki = ok.astype(jnp.int32)
        new = dataclasses.replace(
            self,
            in_head=self.in_head.at[p].set(
                (self.in_head[p] + oki) % self._cap_phys),
            in_cnt=self.in_cnt.at[p].add(-oki),
        )
        return msg, ok, new

    # -- outgoing ---------------------------------------------------------
    def can_send(self, p):
        return self.out_cnt[p] < self.cap[p]

    def send(self, p, msg, when=True):
        """Append ``msg`` to port ``p``'s outgoing buffer (rejects if full).

        Fills the source field and resolves ``dst < 0`` to the port's default
        peer.  Returns ``(new_ports, ok)``.
        """
        ok = self.can_send(p) & jnp.asarray(when, bool)
        oki = ok.astype(jnp.int32)
        msg = msg.at[W_SRC].set(self.gid[p])
        msg = msg.at[W_DST].set(
            jnp.where(msg[W_DST] < 0, self.peer[p], msg[W_DST]))
        tail = (self.out_head[p] + self.out_cnt[p]) % self._cap_phys
        row = self.out_buf[p]                           # [CAP, W]
        oh = (tail == jnp.arange(self._cap_phys)) & ok
        row = jnp.where(oh[:, None], msg[None, :], row)
        new = dataclasses.replace(
            self,
            out_buf=self.out_buf.at[p].set(row),
            out_cnt=self.out_cnt.at[p].add(oki),
        )
        return new, ok

    def in_level(self, p):
        return self.in_cnt[p]

    def out_level(self, p):
        return self.out_cnt[p]
