"""repro.core — the Akita simulation engine, adapted to JAX/TPU.

The paper's primary contribution: an architecture-agnostic, event-driven
simulation engine with Smart Ticking, Availability Backpropagation,
transparent parallelism, task-based tracing, real-time monitoring and trace
visualization.  See DESIGN.md for the Go→JAX adaptation.
"""
from .component import ComponentKind, KindHandle, TickResult
from .engine import (SimBuilder, SimParams, SimState, Simulation, Stats,
                     check_not_consumed)
from .message import (MSG_WORDS, f2i, i2f, msg_new, msg_reply, opcode,
                      payload, ready_time)
from .ports import Ports, oh_set

__all__ = [
    "ComponentKind", "KindHandle", "TickResult", "SimBuilder", "SimParams",
    "SimState", "Simulation", "Stats", "check_not_consumed", "Ports",
    "MSG_WORDS", "msg_new",
    "msg_reply", "opcode", "payload", "ready_time", "f2i", "i2f", "oh_set",
]
