"""Task-based tracing (paper §3.4, Table 2).

Tasks are hierarchical: every task records its parent, so the trace forms a
tree (instruction -> cache miss -> memory transaction).  The instrumentation
API is exactly the paper's three calls — ``start_task`` / ``end_task`` /
``tag_task`` — kept deliberately minimal so hardware-model code stays clean
(AOP separation: the model emits annotations; *tracers* decide what to do
with them).

Two clocks coexist (DESIGN.md §3): host tasks (train steps, checkpoint
saves, sim runs) use wall time; simulation tasks use virtual time — the
caller supplies ``time_fn`` per domain.

Enhanced backtraces (paper Fig. 6b): the active task chain is tracked per
thread; :func:`format_backtrace` renders root→leaf with category/action/
location so a crash shows the *architectural* cause chain alongside the
Python traceback.  Use the :func:`task` context manager to get this
automatically on exceptions.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time as _time
from typing import Any, Callable, Iterable

_id_counter = itertools.count()
_local = threading.local()


def _new_id() -> str:
    return f"t{next(_id_counter):08x}"


@dataclasses.dataclass
class Task:
    """One traced task — the paper's Table 2 record."""

    id: str
    parent_id: str
    category: str
    action: str
    location: str
    start: float
    end: float | None = None
    tags: list[str] = dataclasses.field(default_factory=list)
    details: dict = dataclasses.field(default_factory=dict)

    def row(self) -> tuple:
        return (self.id, self.parent_id, self.category, self.action,
                self.location, self.start,
                -1.0 if self.end is None else self.end,
                json.dumps(self.tags), json.dumps(self.details))

    ROW_FIELDS = ("id", "parent_id", "category", "action", "location",
                  "start", "end", "tags", "details")


def _stack() -> list[Task]:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


def current_task() -> Task | None:
    s = _stack()
    return s[-1] if s else None


class TracingDomain:
    """A set of tracers attached to an instrumented subsystem.

    Akita lets users attach multiple tracers to one component and one tracer
    to many components; here tracers attach to a domain with an optional
    per-tracer filter predicate over tasks.
    """

    def __init__(self, name: str = "default",
                 time_fn: Callable[[], float] = _time.perf_counter):
        self.name = name
        self.time_fn = time_fn
        self._tracers: list[tuple[Any, Callable[[Task], bool] | None]] = []

    # -- tracer management -------------------------------------------------
    def attach(self, tracer, filter: Callable[[Task], bool] | None = None):
        self._tracers.append((tracer, filter))
        return tracer

    def detach(self, tracer):
        self._tracers = [(tr, f) for tr, f in self._tracers if tr is not tracer]

    # -- instrumentation API (paper: StartTask / EndTask / TagTask) --------
    def start_task(self, category: str, action: str, location: str,
                   time: float | None = None, **details) -> Task:
        parent = current_task()
        t = Task(id=_new_id(),
                 parent_id=parent.id if parent else "",
                 category=category, action=action, location=location,
                 start=self.time_fn() if time is None else time,
                 details=details)
        _stack().append(t)
        for tr, f in self._tracers:
            if f is None or f(t):
                tr.on_start(t)
        return t

    def end_task(self, t: Task, time: float | None = None):
        t.end = self.time_fn() if time is None else time
        s = _stack()
        if t in s:
            # pop t and anything mistakenly left above it
            while s and s[-1] is not t:
                s.pop()
            s.pop()
        for tr, f in self._tracers:
            if f is None or f(t):
                tr.on_end(t)

    def tag_task(self, tag: str, t: Task | None = None):
        t = t or current_task()
        if t is None:
            return
        t.tags.append(tag)
        for tr, f in self._tracers:
            if f is None or f(t):
                tr.on_tag(t, tag)

    # -- context-manager sugar ---------------------------------------------
    def task(self, category: str, action: str, location: str, **details):
        return _TaskCtx(self, category, action, location, details)


class _TaskCtx:
    def __init__(self, dom, category, action, location, details):
        self.dom, self.args = dom, (category, action, location)
        self.details = details
        self.t: Task | None = None

    def __enter__(self) -> Task:
        self.t = self.dom.start_task(*self.args, **self.details)
        return self.t

    def __exit__(self, etype, e, tb):
        if etype is not None and self.t is not None:
            # Enhanced backtrace (paper Fig. 6b): print the task chain.
            print(format_backtrace(self.t, header=f"Panic: {e!r}"))
        if self.t is not None:
            self.dom.end_task(self.t)
        return False


def format_backtrace(leaf: Task | None = None, header: str = "Backtrace",
                     chain: Iterable[Task] | None = None) -> str:
    """Render the architectural cause chain root→leaf (paper Fig. 6b)."""
    if chain is None:
        chain = list(_stack())
        if leaf is not None and (not chain or chain[-1] is not leaf):
            chain = chain + [leaf]
    lines = [header]
    for t in chain:
        det = f" {t.details}" if t.details else ""
        lines.append(f"  @{t.location}, {t.category}, {t.action}{det}")
    return "\n".join(lines)


# A module-level default domain for convenience.
default_domain = TracingDomain("default")
start_task = default_domain.start_task
end_task = default_domain.end_task
tag_task = default_domain.tag_task
task = default_domain.task
attach = default_domain.attach
