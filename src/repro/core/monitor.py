"""AkitaRTM-lite: real-time monitoring of running simulations (paper §3.5).

The browser dashboard is replaced by a terminal/JSON dashboard plus an
optional stdlib HTTP endpoint (AkitaRTM "spawns a server when any Akita-based
simulation starts"); the *data model* is the same:

* simulation progress (virtual time, epochs, ticks, progress ratio);
* component inspection (read any component's state fields live);
* buffer-level **bottleneck analyzer** — in a successful simulation all
  buffers drain; persistently non-empty buffers mark the stalled consumer
  (paper's hang-diagnosis recipe);
* **hang detection** — virtual time advancing with no progress ticks, or no
  events left before the horizon;
* ``force_tick`` — force-trigger a component's tick (the paper's breakpoint
  debugging aid).

Implementation: the monitor runs the simulation in host-side chunks
(``run(until=t+chunk)``); between chunks the jitted state is inspected.  This
is the chunked analogue of RTM sampling a live Go process.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

import jax.numpy as jnp
import numpy as np


class _Server(ThreadingHTTPServer):
    # SSE clients (repro.obs.dashboard) hold their handler thread open
    # for the stream's lifetime; shutdown must not wait on them.
    daemon_threads = True
    block_on_close = False


class HttpEndpoint:
    """A stdlib threaded HTTP server with ephemeral-port fallback and a
    clean ``shutdown()`` — the serving half shared by :class:`Monitor`
    (AkitaRTM-lite) and the campaign dashboard
    (:mod:`repro.obs.dashboard`).

    ``port`` is a *request*: when it is already bound (two monitored
    sims in one CI job, a stale server from a previous run) the endpoint
    falls back to an OS-assigned ephemeral port instead of crashing the
    simulation it is observing.  The actually-bound port is on
    ``self.port``; callers report it instead of assuming.
    """

    def __init__(self, handler_cls, port: int = 0,
                 host: str = "127.0.0.1"):
        try:
            self.httpd = _Server((host, int(port)), handler_cls)
        except OSError:
            if int(port) == 0:
                raise               # ephemeral bind failing is terminal
            self.httpd = _Server((host, 0), handler_cls)
        self.host = host
        self.port = int(self.httpd.server_address[1])
        self.requested_port = int(port)
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def shutdown(self) -> None:
        """Stop serving and release the socket (idempotent)."""
        if self.httpd is not None:
            self.httpd.shutdown()
            self.httpd.server_close()
            self._thread.join(timeout=5)
            self.httpd = None


class Monitor:
    def __init__(self, sim, state, domain=None, http_port: int | None = None):
        self.sim = sim
        self.state = state
        self.domain = domain
        self.history: list[dict] = []
        self._bn_cache: list[dict] = []
        self._httpd: HttpEndpoint | None = None
        self.http_port: int | None = None
        if http_port is not None:
            self._serve(http_port)

    # ------------------------------------------------------------------
    def status(self) -> dict:
        s = self.state
        st = s.stats
        ticks = int(st.ticks)
        return {
            "virtual_time": float(s.time),
            "epochs": int(st.epochs),
            "ticks": ticks,
            "progress_ticks": int(st.progress_ticks),
            "progress_ratio": float(int(st.progress_ticks) / max(ticks, 1)),
            "delivered": int(st.delivered),
            "pending_messages": int(jnp.sum(self.sim.flat_in_cnt(s))
                                    + jnp.sum(self.sim.flat_out_cnt(s))),
        }

    def inspect(self, kind: str, inst: int) -> dict:
        """Live component state inspection (RTM's component detail view)."""
        tree = self.state.comp_state[kind]
        import jax
        return {f"f{i}" if not isinstance(k, str) else k:
                np.asarray(v[inst]).tolist()
                for (k, v), i in zip(
                    (tree.items() if isinstance(tree, dict) else
                     enumerate(jax.tree.leaves(tree))),
                    range(10 ** 9))} if isinstance(tree, dict) else {
            f"leaf{i}": np.asarray(v[inst]).tolist()
            for i, v in enumerate(__import__("jax").tree.leaves(tree))}

    def bottleneck_report(self, top: int = 5) -> list[dict]:
        """Fullest buffers first — the RTM Bottleneck Analyzer."""
        s = self.state
        in_cnt = np.asarray(self.sim.flat_in_cnt(s))
        out_cnt = np.asarray(self.sim.flat_out_cnt(s))
        rows = []
        for ki, k in enumerate(self.sim.kinds):
            pb = self.sim.port_base[ki]
            for inst in range(k.n_instances):
                for p in range(k.n_ports):
                    g = pb + inst * k.n_ports + p
                    if in_cnt[g] or out_cnt[g]:
                        rows.append({
                            "port": f"{k.name}[{inst}].p{p}",
                            "in_level": int(in_cnt[g]),
                            "out_level": int(out_cnt[g]),
                            "stalled_consumer": bool(in_cnt[g] > 0),
                        })
        rows.sort(key=lambda r: -(r["in_level"] + r["out_level"]))
        return rows[:top]

    def force_tick(self, kind: str, inst: int):
        """Force-trigger a tick on a suspect component (paper §3.5)."""
        cid = self.sim.comp_id(kind, inst)
        self.state = dataclasses.replace(
            self.state,
            next_tick=self.state.next_tick.at[cid].set(self.state.time))
        self.state = self.sim.run(self.state, until=float(self.state.time))
        return self.status()

    # ------------------------------------------------------------------
    def run_monitored(self, until: float, chunk: float = 1000.0,
                      hang_chunks: int = 3, verbose: bool = True):
        """Run to ``until`` in chunks, reporting progress and detecting hangs.

        Returns (final_state, hang_detected).
        """
        stall = 0
        last_prog = -1
        t = float(self.state.time)
        while t < until:
            t = min(t + chunk, until)
            tk = (self.domain.start_task("monitor", "chunk", "engine")
                  if self.domain else None)
            self.state = self.sim.run(self.state, until=t)
            if tk:
                self.domain.end_task(tk)
            stat = self.status()
            self.history.append(stat)
            if self._httpd:     # refresh the HTTP thread's safe snapshot
                self._bn_cache = self.bottleneck_report()
            if verbose:
                print(f"[RTM] vt={stat['virtual_time']:>10.1f} "
                      f"epochs={stat['epochs']:>8d} "
                      f"progress={stat['progress_ratio']:.2f} "
                      f"pending={stat['pending_messages']}")
            prog = stat["progress_ticks"]
            if prog == last_prog and stat["pending_messages"] > 0:
                stall += 1
                if stall >= hang_chunks:
                    if verbose:
                        print("[RTM] HANG detected — bottleneck analysis:")
                        for row in self.bottleneck_report():
                            print("   ", row)
                    return self.state, True
            else:
                stall = 0
            last_prog = prog
            if stat["pending_messages"] == 0 and \
                    float(self.state.time) >= until:
                break
        return self.state, False

    # ------------------------------------------------------------------
    def _serve(self, port: int):
        """Optional stdlib HTTP endpoint: GET /status, /bottlenecks.

        ``port`` is a request — if it is already in use the monitor
        serves on an ephemeral port instead of crashing; the bound port
        is on ``self.http_port``.
        """
        mon = self

        class H(BaseHTTPRequestHandler):
            def do_GET(self):
                # The engine donates state buffers: while a chunk is being
                # dispatched on the main thread, mon.state's arrays may
                # already be deleted.  Fall back to the last snapshot taken
                # between chunks rather than crashing the endpoint.
                try:
                    body = (mon.status() if self.path != "/bottlenecks"
                            else mon.bottleneck_report())
                except Exception:
                    body = ((mon.history[-1] if mon.history else {})
                            if self.path != "/bottlenecks"
                            else mon._bn_cache)
                body = json.dumps(body).encode()
                self.send_response(200)
                self.send_header("Content-Type", "application/json")
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):
                pass

        self._httpd = HttpEndpoint(H, port=port)
        self.http_port = self._httpd.port

    def shutdown(self):
        """Stop the HTTP endpoint and release its socket (idempotent;
        safe to call when no endpoint was started)."""
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd = None
            self.http_port = None

    # backwards-compatible alias
    def close(self):
        self.shutdown()
