"""The Akita engine in JAX: event-driven core + Smart Ticking (paper §3.2)
+ Availability Backpropagation + transparent vectorized parallelism (§3.3).

Design (see DESIGN.md §3 for the hardware-adaptation rationale):

* Instances of every component kind are rows of batched arrays; one *epoch* of
  a jitted ``lax.while_loop`` advances virtual time straight to the next event
  (``min`` over all wake times) — the event-driven jump that lets Smart
  Ticking skip idle stretches entirely.
* Smart Ticking's four rules (paper §3.2) are vectorized:
    1. message arrival wakes the destination component at the arrival time;
    2. an outgoing buffer going full→not-full wakes its owner;
    3. a tick returning progress reschedules at ``t + period``; otherwise the
       component sleeps (``next_tick = +inf``);
    4. duplicate events are impossible by construction (wakes are ``min``-
       scatters into a single per-component wake time).
* Availability Backpropagation (paper Fig. 5): an incoming buffer going
  full→not-full wakes the serving connection; the connection draining a source
  port's outgoing buffer full→not-full wakes the upstream component — the
  backward chain that makes the sleep rules lossless.
* ``naive=True`` compiles the ablation engine — every component ticks every
  cycle of its clock, connections attempt delivery every cycle — used by the
  Fig. 9a/9b reproduction.  Both engines share the delivery/tick code, so the
  hypothesis equivalence test can require *bit-identical* results.

Parallelism is transparent exactly as the paper demands: ``tick_fn`` is
single-instance, lock-free code; the engine vmaps it over instances (VPU
lanes) and `repro.core.pdes` shards the instance axis over devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .component import ComponentKind, KindHandle, normalize_tick_output
from .message import MSG_WORDS, W_DST, W_TIME, f2i, i2f
from .ports import EPS, Ports

INF = jnp.float32(jnp.inf)


def _align_after(t, period):
    """First grid point of ``period`` strictly after ``t``."""
    return (jnp.floor(t / period + EPS) + 1.0) * period


def _align_at_or_after(t, period):
    """First grid point of ``period`` at or after ``t``."""
    return jnp.ceil(t / period - EPS) * period


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Stats:
    epochs: jax.Array          # i32 — while-loop iterations executed
    ticks: jax.Array           # i32 — component ticks executed
    progress_ticks: jax.Array  # i32 — ticks that made forward progress
    delivered: jax.Array       # i32 — messages moved by connections
    busy: jax.Array            # [NC] i32 — per-component progressing ticks

    @staticmethod
    def zero(n_comp):
        z = jnp.zeros((), jnp.int32)
        return Stats(z, z, z, z, jnp.zeros((n_comp,), jnp.int32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimState:
    time: jax.Array            # f32 scalar — virtual time in cycles
    next_tick: jax.Array       # [NC] f32 — per-component wake time (+inf asleep)
    conn_wake: jax.Array       # [C] f32 — per-connection wake time
    comp_state: dict           # kind name -> pytree with leading [N_k]
    in_buf: jax.Array          # [PG, CAP, W] i32
    in_head: jax.Array         # [PG] i32
    in_cnt: jax.Array          # [PG] i32
    out_buf: jax.Array         # [PG, CAP, W] i32
    out_head: jax.Array        # [PG] i32
    out_cnt: jax.Array         # [PG] i32
    rr: jax.Array              # [C] i32 — round-robin pointers
    stats: Stats
    buf_samples: jax.Array     # [S, PG] i32 in-buffer levels (0-size if off)
    sample_idx: jax.Array      # i32
    next_sample: jax.Array     # f32


class SimBuilder:
    """Builds a static topology: kinds, ports, connections (Akita §3.1)."""

    def __init__(self, msg_words: int = MSG_WORDS):
        assert msg_words == MSG_WORDS
        self.kinds: list[ComponentKind] = []
        self._kind_ix: dict[str, int] = {}
        self.conns: list[tuple[list[tuple[str, int, int]], float]] = []

    def add_kind(self, kind: ComponentKind) -> KindHandle:
        assert kind.name not in self._kind_ix, f"duplicate kind {kind.name}"
        self._kind_ix[kind.name] = len(self.kinds)
        self.kinds.append(kind)
        return KindHandle(kind.name, len(self.kinds) - 1)

    def connect(self, members, latency: float = 1.0):
        """Connect 2+ ports with a round-robin arbitrated crossbar.

        ``latency`` is in cycles and must be >= 1 (a "direct connection" is
        one cycle — no zero-delay loops; see DESIGN.md).
        """
        assert latency >= 1.0 - 1e-6, "connection latency must be >= 1 cycle"
        assert len(members) >= 2
        self.conns.append(([tuple(m) for m in members], float(latency)))
        return len(self.conns) - 1

    # ------------------------------------------------------------------
    def build(self, naive: bool = False, cap_phys: int | None = None,
              sample_period: float = 0.0, max_samples: int = 1024,
              ) -> "Simulation":
        return Simulation(self, naive=naive, cap_phys=cap_phys,
                          sample_period=sample_period,
                          max_samples=max_samples)


class Simulation:
    """A compiled-topology simulation instance."""

    def __init__(self, b: SimBuilder, naive: bool, cap_phys: int | None,
                 sample_period: float, max_samples: int):
        self.kinds = list(b.kinds)
        self.naive = naive
        self.sample_period = float(sample_period)
        self.max_samples = int(max_samples) if sample_period > 0 else 0

        # --- component + port numbering ---------------------------------
        self.comp_base, self.port_base = [], []
        nc = pg = 0
        for k in self.kinds:
            self.comp_base.append(nc)
            self.port_base.append(pg)
            nc += k.n_instances
            pg += k.n_instances * k.n_ports
        self.n_comp, self.n_ports_g = nc, pg

        periods = np.concatenate([k.periods() for k in self.kinds]) \
            if self.kinds else np.zeros((0,), np.float32)
        caps = np.concatenate([k.caps().reshape(-1) for k in self.kinds]) \
            if self.kinds else np.zeros((0,), np.int32)
        port_owner = np.concatenate([
            np.repeat(np.arange(k.n_instances, dtype=np.int32) + self.comp_base[i],
                      k.n_ports)
            for i, k in enumerate(self.kinds)]) if self.kinds else np.zeros((0,), np.int32)
        self.cap_phys = int(cap_phys or max(4, caps.max(initial=1)))
        assert caps.max(initial=1) <= self.cap_phys

        # --- connections -------------------------------------------------
        def pid(ref):
            name, inst, port = ref
            ki = b._kind_ix[name]
            k = self.kinds[ki]
            assert 0 <= inst < k.n_instances and 0 <= port < k.n_ports, ref
            return self.port_base[ki] + inst * k.n_ports + port

        n_conn = max(1, len(b.conns))
        max_m = max([len(m) for m, _ in b.conns], default=2)
        member = np.full((n_conn, max_m), -1, np.int32)
        latency = np.ones((n_conn,), np.float32)
        port_conn = np.full((pg,), -1, np.int32)
        peer = np.full((pg,), -1, np.int32)
        for c, (members, lat) in enumerate(b.conns):
            pids = [pid(m) for m in members]
            assert len(set(pids)) == len(pids), "port connected twice"
            for j, p in enumerate(pids):
                assert port_conn[p] == -1, "each port is served by one connection"
                member[c, j] = p
                port_conn[p] = c
            latency[c] = lat
            if len(pids) == 2:
                peer[pids[0]], peer[pids[1]] = pids[1], pids[0]
        self.n_conn, self.max_m = n_conn, max_m

        # --- constants on device -----------------------------------------
        self.c = dict(
            periods=jnp.asarray(periods), caps=jnp.asarray(caps),
            port_owner=jnp.asarray(port_owner), member=jnp.asarray(member),
            latency=jnp.asarray(latency), port_conn=jnp.asarray(port_conn),
            peer=jnp.asarray(peer),
        )
        self._run_jit = jax.jit(self._run, static_argnames=("max_epochs",))

    # ------------------------------------------------------------------
    def port_id(self, kind_name: str, inst: int, port: int = 0) -> int:
        """Global port id for (kind, instance, port) — for explicit addressing."""
        for ki, k in enumerate(self.kinds):
            if k.name == kind_name:
                assert 0 <= inst < k.n_instances and 0 <= port < k.n_ports
                return self.port_base[ki] + inst * k.n_ports + port
        raise KeyError(kind_name)

    def comp_id(self, kind_name: str, inst: int) -> int:
        for ki, k in enumerate(self.kinds):
            if k.name == kind_name:
                return self.comp_base[ki] + inst
        raise KeyError(kind_name)

    # ------------------------------------------------------------------
    def init_state(self) -> SimState:
        pgt, cap, w = self.n_ports_g, self.cap_phys, MSG_WORDS
        next_tick = []
        for k in self.kinds:
            t0 = INF if k.start_asleep else 0.0
            next_tick.append(jnp.full((k.n_instances,), t0, jnp.float32))
        return SimState(
            time=jnp.float32(0.0),
            next_tick=(jnp.concatenate(next_tick) if next_tick
                       else jnp.zeros((0,), jnp.float32)),
            conn_wake=jnp.full((self.n_conn,), INF),
            comp_state={k.name: k.init_state for k in self.kinds},
            in_buf=jnp.zeros((pgt, cap, w), jnp.int32),
            in_head=jnp.zeros((pgt,), jnp.int32),
            in_cnt=jnp.zeros((pgt,), jnp.int32),
            out_buf=jnp.zeros((pgt, cap, w), jnp.int32),
            out_head=jnp.zeros((pgt,), jnp.int32),
            out_cnt=jnp.zeros((pgt,), jnp.int32),
            rr=jnp.zeros((self.n_conn,), jnp.int32),
            stats=Stats.zero(self.n_comp),
            # min 1 row: zero-sized arrays break shard_map sharding (pdes)
            buf_samples=jnp.zeros((max(self.max_samples, 1), pgt), jnp.int32),
            sample_idx=jnp.int32(0),
            next_sample=jnp.float32(self.sample_period if self.sample_period
                                    else jnp.inf),
        )

    # ------------------------------------------------------------------
    # Delivery phase: round-robin arbitrated crossbar per connection.
    def _deliver(self, s: SimState, t, active, wake_comp):
        c = self.c
        C, M = self.n_conn, self.max_m
        mp = c["member"]                       # [C, M]
        valid = mp >= 0
        mps = jnp.maximum(mp, 0)
        have = (s.out_cnt[mps] > 0) & valid & active[:, None]
        head = s.out_buf[mps, s.out_head[mps]]           # [C, M, W]
        dst = head[:, :, W_DST]
        dsts = jnp.clip(dst, 0, self.n_ports_g - 1)
        space = s.in_cnt[dsts] < c["caps"][dsts]
        req = have & space & (dst >= 0)
        prio = (jnp.arange(M, dtype=jnp.int32)[None, :] - s.rr[:, None]) % M
        # m loses if some m2 requests the same destination with lower prio.
        beats = (req[:, None, :] & (dst[:, :, None] == dst[:, None, :])
                 & (prio[:, None, :] < prio[:, :, None]))
        win = req & ~jnp.any(beats, axis=2)              # [C, M]

        win_f = win.reshape(-1)
        drop_p = jnp.int32(self.n_ports_g)               # out-of-bounds => drop
        src_f = jnp.where(win_f, mps.reshape(-1), drop_p)
        dst_f = jnp.where(win_f, dsts.reshape(-1), drop_p)
        lat_f = jnp.repeat(c["latency"], M)
        arrive = t + lat_f
        msg_f = head.reshape(-1, MSG_WORDS).at[:, W_TIME].set(f2i(arrive))

        full_before_out = s.out_cnt == c["caps"]
        # pop winners from source out-buffers
        out_cnt = s.out_cnt.at[src_f].add(-1, mode="drop")
        out_head = s.out_head.at[src_f].add(1, mode="drop") % self.cap_phys
        # push into destination in-buffers
        tail_f = (s.in_head[dst_f % self.n_ports_g]
                  + s.in_cnt[dst_f % self.n_ports_g]) % self.cap_phys
        in_buf = s.in_buf.at[dst_f, tail_f].set(msg_f, mode="drop")
        in_cnt = s.in_cnt.at[dst_f].add(1, mode="drop")

        # Rule 1: message arrival wakes the destination component.
        drop_c = jnp.int32(self.n_comp)
        own_dst = jnp.where(win_f, c["port_owner"][dst_f % self.n_ports_g], drop_c)
        per_dst = c["periods"][own_dst % max(self.n_comp, 1)]
        wake_comp = wake_comp.at[own_dst].min(
            _align_at_or_after(arrive, per_dst), mode="drop")
        # Rule 2 / backprop forward half: freed source out-buffer wakes owner.
        freed = win_f & full_before_out[src_f % self.n_ports_g]
        own_src = jnp.where(freed, c["port_owner"][src_f % self.n_ports_g], drop_c)
        per_src = c["periods"][own_src % max(self.n_comp, 1)]
        wake_comp = wake_comp.at[own_src].min(
            _align_after(t, per_src), mode="drop")

        # round-robin pointer: advance past the last-served winner
        gp = jnp.where(win, prio, -1)
        any_win = jnp.any(win, axis=1)
        last = jnp.argmax(gp, axis=1).astype(jnp.int32)
        rr = jnp.where(any_win, (last + 1) % M, s.rr)

        # connection self-scheduling: if it delivered and work remains, wake
        # next cycle; otherwise sleep (backprop / sends will wake it).
        pending = jnp.any(valid & (out_cnt[mps] > 0), axis=1)
        nw = jnp.where(any_win & pending, _align_after(t, 1.0), INF)
        conn_wake = jnp.where(active, nw, s.conn_wake)

        delivered = jnp.sum(win_f.astype(jnp.int32))
        s = dataclasses.replace(
            s, in_buf=in_buf, in_cnt=in_cnt, out_buf=s.out_buf,
            out_cnt=out_cnt, out_head=out_head, rr=rr, conn_wake=conn_wake,
            stats=dataclasses.replace(s.stats,
                                      delivered=s.stats.delivered + delivered))
        return s, wake_comp

    # ------------------------------------------------------------------
    # Tick phase: vmap each kind's tick_fn over its to-run instances.
    def _tick_kinds(self, s: SimState, t, wake_conn):
        c = self.c
        next_tick = s.next_tick
        in_buf, in_head, in_cnt = s.in_buf, s.in_head, s.in_cnt
        out_buf, out_head, out_cnt = s.out_buf, s.out_head, s.out_cnt
        comp_state = dict(s.comp_state)
        total_ticks = jnp.int32(0)
        total_prog = jnp.int32(0)
        busy = s.stats.busy

        for ki, kind in enumerate(self.kinds):
            n, p = kind.n_instances, kind.n_ports
            cb, pb = self.comp_base[ki], self.port_base[ki]
            csl = slice(cb, cb + n)
            psl = slice(pb, pb + n * p)
            if self.naive:
                mask = jnp.abs(jnp.remainder(t, c["periods"][csl])) < EPS
                mask = mask | (jnp.abs(jnp.remainder(t, c["periods"][csl])
                                       - c["periods"][csl]) < EPS)
            else:
                mask = next_tick[csl] <= t + EPS

            sh = lambda a: a[psl].reshape(n, p, *a.shape[1:])
            gid = jnp.arange(pb, pb + n * p, dtype=jnp.int32).reshape(n, p)

            def one(st_i, ib, ih, ic, ob, oh, oc, cp, g, pe, kind=kind):
                ports = Ports(ib, ih, ic, ob, oh, oc, cp, g, pe,
                              jnp.asarray(t, jnp.float32))
                st2, ports2, res = normalize_tick_output(
                    kind.tick_fn(st_i, ports, jnp.asarray(t, jnp.float32)))
                return (st2, ports2.in_buf, ports2.in_head, ports2.in_cnt,
                        ports2.out_buf, ports2.out_head, ports2.out_cnt,
                        res.progress, res.next_time)

            (st2, ib2, ih2, ic2, ob2, oh2, oc2, prog, nxt) = jax.vmap(one)(
                comp_state[kind.name], sh(in_buf), sh(in_head), sh(in_cnt),
                sh(out_buf), sh(out_head), sh(out_cnt),
                c["caps"][psl].reshape(n, p), gid,
                c["peer"][psl].reshape(n, p))

            def sel(new, old, m=mask):
                mm = m.reshape(m.shape + (1,) * (new.ndim - 1))
                return jnp.where(mm, new, old)

            comp_state[kind.name] = jax.tree.map(
                lambda a, b: sel(a, b), st2, comp_state[kind.name])
            fl = lambda a: a.reshape(n * p, *a.shape[2:])
            pmask = jnp.repeat(mask, p)

            def psel(new, old):
                mm = pmask.reshape(pmask.shape + (1,) * (new.ndim - 1))
                return jnp.where(mm, new, old)

            ic_old = in_cnt[psl]
            oc_old = out_cnt[psl]
            in_buf = in_buf.at[psl].set(psel(fl(ib2), in_buf[psl]))
            in_head = in_head.at[psl].set(psel(fl(ih2), in_head[psl]))
            in_cnt = in_cnt.at[psl].set(psel(fl(ic2), in_cnt[psl]))
            out_buf = out_buf.at[psl].set(psel(fl(ob2), out_buf[psl]))
            out_head = out_head.at[psl].set(psel(fl(oh2), out_head[psl]))
            out_cnt = out_cnt.at[psl].set(psel(fl(oc2), out_cnt[psl]))

            prog = prog & mask
            if not self.naive:
                # Rule 3: progress => next cycle; no progress => sleep.
                base = jnp.where(prog, _align_after(t, c["periods"][csl]), INF)
                custom = jnp.where(nxt > -0.5, jnp.maximum(nxt, t + EPS), base)
                # In-flight arrivals: a ticked component must not sleep past
                # the ready time of a message already in its buffers (rule 1
                # for arrivals whose delivery preceded this tick).  Ready-now
                # messages do NOT re-wake — unblocking is backprop's job.
                hb = in_buf[psl][:, :, W_TIME]              # [n*p, CAP]
                hr = i2f(jnp.take_along_axis(
                    hb, in_head[psl][:, None], axis=1)[:, 0])
                pend = (in_cnt[psl] > 0) & (hr > t + EPS)
                w = jnp.where(pend, hr, INF).reshape(n, p)
                arr = _align_at_or_after(jnp.min(w, axis=1),
                                         c["periods"][csl])
                custom = jnp.minimum(custom, arr)
                next_tick = next_tick.at[csl].set(
                    jnp.where(mask, custom, next_tick[csl]))

            # Availability Backpropagation (backward half): incoming buffer
            # full->not-full wakes the serving connection; any new send wakes
            # the connection too.
            caps_p = c["caps"][psl]
            ic_new, oc_new = in_cnt[psl], out_cnt[psl]
            in_freed = (ic_old == caps_p) & (ic_new < caps_p)
            sent = oc_new > oc_old
            wake_p = in_freed | sent
            drop_c = jnp.int32(self.n_conn)
            conns = jnp.where(wake_p, c["port_conn"][psl], drop_c)
            conns = jnp.where(conns < 0, drop_c, conns)
            wake_conn = wake_conn.at[conns].min(_align_after(t, 1.0),
                                                mode="drop")

            total_ticks += jnp.sum(mask.astype(jnp.int32))
            total_prog += jnp.sum(prog.astype(jnp.int32))
            busy = busy.at[csl].add(prog.astype(jnp.int32))

        stats = dataclasses.replace(
            s.stats, ticks=s.stats.ticks + total_ticks,
            progress_ticks=s.stats.progress_ticks + total_prog, busy=busy)
        s = dataclasses.replace(
            s, next_tick=next_tick, comp_state=comp_state, in_buf=in_buf,
            in_head=in_head, in_cnt=in_cnt, out_buf=out_buf,
            out_head=out_head, out_cnt=out_cnt, stats=stats)
        return s, wake_conn

    # ------------------------------------------------------------------
    def _epoch(self, s: SimState, until):
        if self.naive:
            t = s.time  # process the current cycle, then advance by one
            active = jnp.ones((self.n_conn,), bool)
        else:
            t = jnp.minimum(jnp.min(s.next_tick) if self.n_comp else INF,
                            jnp.min(s.conn_wake))
            if self.max_samples:
                t = jnp.minimum(t, s.next_sample)
            active = s.conn_wake <= t + EPS

        wake_comp = jnp.full((self.n_comp,), INF)
        wake_conn = jnp.full((self.n_conn,), INF)
        s = dataclasses.replace(s, time=t)
        s, wake_comp = self._deliver(s, t, active, wake_comp)
        s, wake_conn = self._tick_kinds(s, t, wake_conn)
        s = dataclasses.replace(
            s,
            next_tick=jnp.minimum(s.next_tick, wake_comp),
            conn_wake=jnp.minimum(s.conn_wake, wake_conn),
            stats=dataclasses.replace(s.stats, epochs=s.stats.epochs + 1))
        if self.max_samples:
            do = s.next_sample <= t + EPS
            row = s.sample_idx % self.max_samples
            s = dataclasses.replace(
                s,
                buf_samples=jnp.where(
                    do, s.buf_samples.at[row].set(s.in_cnt), s.buf_samples),
                sample_idx=s.sample_idx + do.astype(jnp.int32),
                next_sample=jnp.where(do, s.next_sample + self.sample_period,
                                      s.next_sample))
        if self.naive:
            s = dataclasses.replace(s, time=t + 1.0)
        return s

    def _next_event(self, s: SimState):
        t = jnp.min(s.next_tick) if self.n_comp else INF
        t = jnp.minimum(t, jnp.min(s.conn_wake))
        if self.max_samples:
            t = jnp.minimum(t, s.next_sample)
        return t

    def _run(self, s: SimState, until, max_epochs):
        until = jnp.asarray(until, jnp.float32)

        def cond(s):
            if self.naive:
                more = s.time <= until + EPS
            else:
                more = self._next_event(s) <= until + EPS
            return more & (s.stats.epochs < max_epochs)

        return jax.lax.while_loop(cond, lambda s: self._epoch(s, until), s)

    def run(self, state: SimState, until: float,
            max_epochs: int = 2_000_000) -> SimState:
        """Advance the simulation to virtual time ``until`` (cycles)."""
        assert until < 2 ** 24, "float32 cycle precision bound (DESIGN.md)"
        return self._run_jit(state, until, max_epochs=max_epochs)
