"""The Akita engine in JAX: event-driven core + Smart Ticking (paper §3.2)
+ Availability Backpropagation + transparent vectorized parallelism (§3.3).

Design (see DESIGN.md §3 for the hardware-adaptation rationale):

* Instances of every component kind are rows of batched arrays; one *epoch* of
  a jitted ``lax.while_loop`` advances virtual time straight to the next event
  (``min`` over all wake times) — the event-driven jump that lets Smart
  Ticking skip idle stretches entirely.
* Smart Ticking's four rules (paper §3.2) are vectorized:
    1. message arrival wakes the destination component at the arrival time;
    2. an outgoing buffer going full→not-full wakes its owner;
    3. a tick returning progress reschedules at ``t + period``; otherwise the
       component sleeps (``next_tick = +inf``);
    4. duplicate events are impossible by construction (wakes are ``min``-
       scatters into a single per-component wake time).
* Availability Backpropagation (paper Fig. 5): an incoming buffer going
  full→not-full wakes the serving connection; the connection draining a source
  port's outgoing buffer full→not-full wakes the upstream component — the
  backward chain that makes the sleep rules lossless.
* ``naive=True`` compiles the ablation engine — every component ticks every
  cycle of its clock, connections attempt delivery every cycle — used by the
  Fig. 9a/9b reproduction.  Both engines share the delivery/tick code, so the
  hypothesis equivalence test can require *bit-identical* results.

Hot-loop performance architecture (see ENGINE_PERF.md):

* **Segmented port state** — port ring buffers live in per-kind segments
  (``SimState.in_buf`` etc. are dicts keyed by kind name, mirroring
  ``comp_state``), so a kind's tick phase reads and writes *only its own
  segment*; the old layout needed a gather plus a full-array scatter per
  port array per kind per epoch.  ``_deliver`` materializes flat views
  lazily (concats of these small arrays are ~free) and is *scatter-free*:
  on CPU XLA a scatter costs two orders of magnitude more than the
  equivalent static-index take or one-hot select at these array sizes, so
  every dynamically-indexed update is reformulated as static takes
  (connection membership is a build-time constant) plus one-hot
  multiply/reduce over the destination-port axis.
* **Super-epoch fusion** — ``_run`` executes ``super_epoch`` (K) epochs per
  ``while_loop`` iteration via an inner ``lax.scan`` whose steps are guarded
  by ``lax.cond``: steps past the horizon are exact no-ops, so fused runs
  are bit-identical to K=1 runs while amortizing loop-condition evaluation
  and letting XLA fuse across epochs.  K is picked heuristically from the
  topology size and exposed as the ``super_epoch`` build knob (K=1 is the
  compatibility path).
* **Zero-copy stepping** — ``run()`` donates the ``SimState`` into the jitted
  loop (``donate_argnums``) so the big message buffers are updated in place
  instead of round-tripped; a donated input state must not be reused by the
  caller (use :meth:`Simulation.copy_state` first, or build with
  ``donate=False``).
* **Hoisted constants** — per-kind static index arrays (port slices, global
  port ids, capacity/peer slices, connection-membership masks) are
  precomputed once at build time instead of re-derived every epoch.
* **Static/traced split (DSE.md)** — structure (topology, wiring,
  capacities) stays a build-time constant, while the numeric timing/model
  knobs (connection latencies, per-kind tick periods, opt-in per-kind
  model params) live in a traced :class:`SimParams` pytree threaded
  through ``run()``: one compiled loop serves every design point of a
  structure, and ``repro.dse`` vmaps it over stacked param batches.

Parallelism is transparent exactly as the paper demands: ``tick_fn`` is
single-instance, lock-free code; the engine vmaps it over instances (VPU
lanes) and `repro.core.pdes` shards the instance axis over devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .component import ComponentKind, KindHandle, normalize_tick_output
from .message import MSG_WORDS, W_DST, W_TIME, f2i, i2f
from .ports import EPS, Ports

INF = jnp.float32(jnp.inf)


def check_not_consumed(state) -> None:
    """Raise a clear error if ``state`` was already donated into a run.

    A donating ``run()`` consumes its input ``SimState`` — the buffers are
    released to the output (``is_deleted()`` turns true on the input's
    arrays).  Reusing it would otherwise surface as XLA's opaque
    deleted-buffer failure deep inside dispatch; this check turns that
    into an actionable message up front.
    """
    dead = [leaf for leaf in jax.tree.leaves(state)
            if getattr(leaf, "is_deleted", lambda: False)()]
    if dead:
        raise RuntimeError(
            "this SimState was already consumed by a donating run() — its "
            f"buffers are deleted ({len(dead)} leaves). Keep using the "
            "state a donating run *returns*; to reuse an input state, "
            "deep-copy it first (sim.copy_state(state)) or build the "
            "simulation with donate=False (see ENGINE_PERF.md).")


def _align_after(t, period):
    """First grid point of ``period`` strictly after ``t``."""
    return (jnp.floor(t / period + EPS) + 1.0) * period


def _align_at_or_after(t, period):
    """First grid point of ``period`` at or after ``t``."""
    return jnp.ceil(t / period - EPS) * period


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimParams:
    """Traced timing/model parameters of a compiled topology (DSE.md).

    The build splits the simulation's configuration in two: *structure*
    (topology, port wiring, buffer capacities, kind/instance counts) stays
    a hoisted build-time constant, while the *numeric knobs* below are a
    pytree threaded through the jitted hot loop as ordinary traced
    operands.  One compiled simulation therefore serves every design point
    that shares a structure: ``run(..., params=p)`` re-runs without
    recompiling, and ``repro.dse`` vmaps the loop over a stacked
    ``SimParams`` batch to simulate hundreds of configurations at once.

    Leaves (all shapes are per-topology static):
      * ``conn_latency`` — ``[C]`` f32 connection latencies in cycles
        (must stay >= 1; the no-zero-delay contract of ``connect`` is a
        structural invariant the trace cannot re-check).
      * ``periods`` — dict kind name -> ``[n_instances]`` f32 tick periods.
      * ``kind`` — dict kind name -> that kind's opt-in model-parameter
        pytree (``ComponentKind.params``; ``{}`` for kinds without one),
        passed as the 4th argument to a 4-ary ``tick_fn``.
      * ``inst_mask`` — dict kind name -> ``[n_instances]`` bool *activity
        masks* (``None`` = everything active, the default).  A masked-off
        instance never ticks, is pinned to ``next_tick = +inf`` (excluded
        from the next-event min) and contributes nothing to the tick/
        progress stats — so a *topology family* built at its maximum shape
        (``SimBuilder.build(pad_shape=...)``) simulates any sub-shape by
        mask alone, without rebuilding or recompiling (DSE.md).
      * ``conn_mask`` — ``[C]`` bool (``None`` = all active).  A masked-off
        connection never delivers and is pinned to ``conn_wake = +inf``.
        ``Simulation.prefix_masks`` derives both masks for a prefix
        sub-shape of a family.

    Params enter the loop as broadcast operands only — never as gather or
    scatter indices — so the scatter-free hot-loop property (ENGINE_PERF.md)
    is preserved under both tracing and batch vmapping; the masks in
    particular act through broadcast ``&``/``where`` selects.
    """

    conn_latency: jax.Array    # [C] f32
    periods: dict              # kind name -> [n_k] f32
    kind: dict                 # kind name -> params pytree ({} if none)
    inst_mask: Any = None      # kind name -> [n_k] bool, or None (all on)
    conn_mask: Any = None      # [C] bool, or None (all on)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Stats:
    epochs: jax.Array          # i32 — while-loop iterations executed
    ticks: jax.Array           # i32 — component ticks executed
    progress_ticks: jax.Array  # i32 — ticks that made forward progress
    delivered: jax.Array       # i32 — messages moved by connections
    busy: jax.Array            # [NC] i32 — per-component progressing ticks

    @staticmethod
    def zero(n_comp):
        # distinct buffers per field: aliased leaves cannot be donated
        z = lambda: jnp.zeros((), jnp.int32)
        return Stats(z(), z(), z(), z(), jnp.zeros((n_comp,), jnp.int32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimState:
    """Engine state.  Port arrays are *per-kind segments*: dicts keyed by
    kind name whose values are flat over that kind's ports
    (``[N_k * P_k, ...]``, instance-major).  Flat global views (ordered by
    kind registration, i.e. global port id) are materialized on demand via
    ``Simulation.flat_in_cnt`` and friends."""

    time: jax.Array            # f32 scalar — virtual time in cycles
    next_tick: jax.Array       # [NC] f32 — per-component wake time (+inf asleep)
    conn_wake: jax.Array       # [C] f32 — per-connection wake time
    comp_state: dict           # kind name -> pytree with leading [N_k]
    in_buf: dict               # kind name -> [NP_k, CAP, W] i32
    in_head: dict              # kind name -> [NP_k] i32
    in_cnt: dict               # kind name -> [NP_k] i32
    out_buf: dict              # kind name -> [NP_k, CAP, W] i32
    out_head: dict             # kind name -> [NP_k] i32
    out_cnt: dict              # kind name -> [NP_k] i32
    rr: jax.Array              # [C] i32 — round-robin pointers
    stats: Stats
    buf_samples: jax.Array     # [S, PG] i32 in-buffer levels (0-size if off)
    sample_idx: jax.Array      # i32
    next_sample: jax.Array     # f32


@dataclasses.dataclass
class _KindConsts:
    """Per-kind constants hoisted out of the hot loop at build time."""

    name: str
    n: int                     # instances
    p: int                     # ports per instance
    np_k: int                  # n * p
    cb: int                    # component base id
    pb: int                    # global port base id
    csl: slice                 # global component slice
    periods: jax.Array         # [n] f32
    caps: jax.Array            # [n, p] i32
    caps_f: jax.Array          # [n*p] i32
    gid: jax.Array             # [n, p] i32 global port ids
    peer: jax.Array            # [n, p] i32 default peers


class SimBuilder:
    """Builds a static topology: kinds, ports, connections (Akita §3.1)."""

    def __init__(self, msg_words: int = MSG_WORDS):
        assert msg_words == MSG_WORDS
        self.kinds: list[ComponentKind] = []
        self._kind_ix: dict[str, int] = {}
        self.conns: list[tuple[list[tuple[str, int, int]], float]] = []

    def add_kind(self, kind: ComponentKind) -> KindHandle:
        assert kind.name not in self._kind_ix, f"duplicate kind {kind.name}"
        self._kind_ix[kind.name] = len(self.kinds)
        self.kinds.append(kind)
        return KindHandle(kind.name, len(self.kinds) - 1)

    def connect(self, members, latency: float = 1.0):
        """Connect 2+ ports with a round-robin arbitrated crossbar.

        ``latency`` is in cycles and must be >= 1 (a "direct connection" is
        one cycle — no zero-delay loops; see DESIGN.md).
        """
        assert latency >= 1.0 - 1e-6, "connection latency must be >= 1 cycle"
        assert len(members) >= 2
        self.conns.append(([tuple(m) for m in members], float(latency)))
        return len(self.conns) - 1

    # ------------------------------------------------------------------
    def build(self, naive: bool = False, cap_phys: int | None = None,
              sample_period: float = 0.0, max_samples: int = 1024,
              super_epoch: int | None = None, donate: bool = True,
              pad_shape: dict[str, int] | None = None) -> "Simulation":
        """Compile the topology.

        ``super_epoch`` — epochs fused per ``while_loop`` iteration (None =
        heuristic from topology size, 1 = unfused compatibility path).
        ``donate`` — donate ``SimState`` into the jitted run so buffers are
        updated in place; callers must then treat the state passed to
        ``run()`` as consumed (see ENGINE_PERF.md).
        ``pad_shape`` — kind name -> instance count: size every named
        kind's segments to a *topology family* maximum before compiling
        (padded instances get zero-filled init rows and repeat the last
        declared period/capacity row).  Connections may wire the padded
        instances — membership is validated against the padded counts — so
        one build at the family maximum serves every sub-shape via the
        ``SimParams.inst_mask`` / ``conn_mask`` activity masks (DSE.md).
        """
        return Simulation(self, naive=naive, cap_phys=cap_phys,
                          sample_period=sample_period,
                          max_samples=max_samples,
                          super_epoch=super_epoch, donate=donate,
                          pad_shape=pad_shape)


def _pad_kind(k: ComponentKind, n_max: int) -> ComponentKind:
    """Pad a kind's instance axis to a family maximum: zero init rows,
    last-row periods/caps.  Padded rows only ever run when unmasked (a
    degenerate but legal all-active run); under ``inst_mask`` they are
    inert."""
    n = k.n_instances
    assert n_max >= n, f"pad_shape[{k.name!r}]={n_max} < declared {n}"
    if n_max == n:
        return k
    pad = n_max - n
    init = jax.tree.map(
        lambda a: jnp.concatenate(
            [jnp.asarray(a),
             jnp.zeros((pad,) + jnp.asarray(a).shape[1:],
                       jnp.asarray(a).dtype)]), k.init_state)
    periods = np.concatenate([k.periods(), np.repeat(k.periods()[-1:], pad)])
    caps = np.concatenate([k.caps(), np.repeat(k.caps()[-1:], pad, axis=0)])
    return dataclasses.replace(k, n_instances=n_max, init_state=init,
                               period=periods, cap=caps)


class Simulation:
    """A compiled-topology simulation instance."""

    def __init__(self, b: SimBuilder, naive: bool, cap_phys: int | None,
                 sample_period: float, max_samples: int,
                 super_epoch: int | None = None, donate: bool = True,
                 pad_shape: dict[str, int] | None = None):
        pad_shape = pad_shape or {}
        unknown = set(pad_shape) - {k.name for k in b.kinds}
        assert not unknown, f"pad_shape names unknown kinds {sorted(unknown)}"
        self.kinds = [_pad_kind(k, pad_shape[k.name])
                      if k.name in pad_shape else k for k in b.kinds]
        self.naive = naive
        self.donate = donate
        self.sample_period = float(sample_period)
        self.max_samples = int(max_samples) if sample_period > 0 else 0

        # --- component + port numbering ---------------------------------
        self.comp_base, self.port_base = [], []
        nc = pg = 0
        for k in self.kinds:
            self.comp_base.append(nc)
            self.port_base.append(pg)
            nc += k.n_instances
            pg += k.n_ports_total
        self.n_comp, self.n_ports_g = nc, pg

        if super_epoch is None:
            # Measured on CPU XLA (ENGINE_PERF.md): with the scatter-free
            # epoch body the loop boundary is cheap, so modest fusion is
            # enough; large topologies pay more per masked tail step and
            # per unrolled-copy compile time, so they stay unfused.
            super_epoch = 2 if pg <= 4096 else 1
        self.super_epoch = max(1, int(super_epoch))

        periods = np.concatenate([k.periods() for k in self.kinds]) \
            if self.kinds else np.zeros((0,), np.float32)
        caps = np.concatenate([k.caps().reshape(-1) for k in self.kinds]) \
            if self.kinds else np.zeros((0,), np.int32)
        self.cap_phys = int(cap_phys or max(4, caps.max(initial=1)))
        assert caps.max(initial=1) <= self.cap_phys

        # --- connections -------------------------------------------------
        def pid(ref):
            name, inst, port = ref
            ki = b._kind_ix[name]
            k = self.kinds[ki]
            assert 0 <= inst < k.n_instances and 0 <= port < k.n_ports, ref
            return self.port_base[ki] + inst * k.n_ports + port

        n_conn = max(1, len(b.conns))
        max_m = max([len(m) for m, _ in b.conns], default=2)
        member = np.full((n_conn, max_m), -1, np.int32)
        latency = np.ones((n_conn,), np.float32)
        port_conn = np.full((pg,), -1, np.int32)
        peer = np.full((pg,), -1, np.int32)
        for c, (members, lat) in enumerate(b.conns):
            pids = [pid(m) for m in members]
            assert len(set(pids)) == len(pids), "port connected twice"
            for j, p in enumerate(pids):
                assert port_conn[p] == -1, "each port is served by one connection"
                member[c, j] = p
                port_conn[p] = c
            latency[c] = lat
            if len(pids) == 2:
                peer[pids[0]], peer[pids[1]] = pids[1], pids[0]
        self.n_conn, self.max_m = n_conn, max_m

        # --- constants on device (only entries the hot loop / pdes still
        # read; member/latency/periods live on as the hoisted static copies
        # below and as SimParams defaults — edit those, not this dict) ----
        self.c = dict(
            caps=jnp.asarray(caps), port_conn=jnp.asarray(port_conn),
            peer=jnp.asarray(peer),
        )
        self._periods_np, self._caps_np = periods, caps
        self._latency_np = latency
        # --- hoisted delivery constants (scatter-free formulation) -------
        # slot_of_port: inverse of the member matrix — each port is served
        # by at most one connection slot, so winner pops become static takes.
        CM = n_conn * max_m
        slot = np.full((pg + 1,), CM, np.int32)
        flat_m = member.reshape(-1)
        for sl_ix, g in enumerate(flat_m):
            if g >= 0:
                slot[g] = sl_ix
        self._slot_of_port = slot[:pg]
        self._mps_np = np.maximum(member, 0)
        self._valid_np = member >= 0
        self._mps_j = jnp.asarray(self._mps_np)
        # member matrix with invalid slots pointing past the wake-mask pad
        self._member_sent_np = np.where(member >= 0, member, pg)
        self._apg = np.arange(pg, dtype=np.int32)                 # [PG]
        self._acap = np.arange(self.cap_phys, dtype=np.int32)     # [CAP]
        self._am = np.arange(max_m, dtype=np.int32)               # [M]
        self._acm = np.arange(CM, dtype=np.int32)                 # [C*M]
        self._build_kind_consts()
        self._dp = self.default_params()
        # until/max_epochs are traced operands (not static): one compiled
        # loop serves every horizon and epoch budget, and repro.dse can
        # vmap them per lane so each lane freezes at its own horizon.
        self._jit_kwargs: dict[str, Any] = {}
        if donate:
            self._jit_kwargs["donate_argnums"] = (0,)
        self._run_jit = jax.jit(self._run, **self._jit_kwargs)

    # ------------------------------------------------------------------
    def _build_kind_consts(self):
        """Hoist per-kind static index/constant arrays out of the hot loop."""
        self._kc = []
        peer = np.asarray(self.c["peer"])
        for ki, k in enumerate(self.kinds):
            n, p = k.n_instances, k.n_ports
            np_k = n * p
            cb, pb = self.comp_base[ki], self.port_base[ki]
            self._kc.append(_KindConsts(
                name=k.name, n=n, p=p, np_k=np_k, cb=cb, pb=pb,
                csl=slice(cb, cb + n),
                periods=jnp.asarray(self._periods_np[cb:cb + n]),
                caps=jnp.asarray(self._caps_np[pb:pb + np_k].reshape(n, p)),
                caps_f=jnp.asarray(self._caps_np[pb:pb + np_k]),
                gid=jnp.arange(pb, pb + np_k, dtype=jnp.int32).reshape(n, p),
                peer=jnp.asarray(peer[pb:pb + np_k].reshape(n, p))))

    def default_params(self) -> SimParams:
        """The :class:`SimParams` this topology was built with.

        Running with ``params=None`` is equivalent to (and compiles the
        same program as) running with these values baked in as constants;
        override leaves (or stack many variants — ``repro.dse``) to
        explore other design points without rebuilding or recompiling.
        """
        return SimParams(
            conn_latency=jnp.asarray(self._latency_np),
            periods={kc.name: kc.periods for kc in self._kc},
            kind={k.name: (jax.tree.map(jnp.asarray, k.params)
                           if k.params is not None else {})
                  for k in self.kinds})

    def prefix_masks(self, counts: dict[str, int]
                     ) -> tuple[dict, jax.Array]:
        """Activity masks for a *prefix sub-shape* of this topology.

        ``counts`` maps kind names to active instance counts (unnamed
        kinds stay fully active); instances ``0..count-1`` of each kind
        are active.  Returns ``(inst_mask, conn_mask)`` for
        :class:`SimParams`: a connection is active iff any of its member
        ports belongs to an active instance — so per-instance links
        between masked instances go quiet while shared fabrics (a family
        crossbar with masked member ports) stay live.

        The prefix discipline is what keeps masked runs bit-identical to
        an unpadded build of the sub-shape (DSE.md): variable-count
        members must occupy the leading member slots of their connection
        in instance order, fixed members the trailing slots, so
        round-robin arbitration sees the same relative slot order at
        every shape.
        """
        unknown = set(counts) - {k.name for k in self.kinds}
        assert not unknown, f"unknown kinds {sorted(unknown)}"
        inst, act = {}, []
        for k in self.kinds:
            n = int(counts.get(k.name, k.n_instances))
            assert 0 <= n <= k.n_instances, (k.name, n, k.n_instances)
            m = np.arange(k.n_instances) < n
            inst[k.name] = jnp.asarray(m)
            act.append(np.repeat(m, k.n_ports))
        port_act = (np.concatenate(act) if act else np.zeros((0,), bool))
        conn = np.any(self._valid_np & port_act[self._mps_np], axis=1)
        return inst, jnp.asarray(conn)

    def _flat_inst_mask(self, inst_mask: dict) -> jax.Array:
        """[NC] bool — per-component activity, ordered by kind
        registration (component id order)."""
        parts = [inst_mask[k.name] for k in self.kinds]
        if not parts:
            return jnp.zeros((0,), bool)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def set_default_peers(self, mapping: dict[int, int]):
        """Rewrite default peers (global port id -> peer port id) and refresh
        the hoisted per-kind constants.  Safe at any time: the jitted run is
        re-wrapped so traces that baked the old constants are discarded."""
        peer = np.asarray(self.c["peer"]).copy()
        for src, dst in mapping.items():
            peer[src] = dst
        self.c["peer"] = jnp.asarray(peer)
        self._build_kind_consts()
        self._run_jit = jax.jit(self._run, **self._jit_kwargs)

    # ------------------------------------------------------------------
    def port_id(self, kind_name: str, inst: int, port: int = 0) -> int:
        """Global port id for (kind, instance, port) — for explicit addressing."""
        for ki, k in enumerate(self.kinds):
            if k.name == kind_name:
                assert 0 <= inst < k.n_instances and 0 <= port < k.n_ports
                return self.port_base[ki] + inst * k.n_ports + port
        raise KeyError(kind_name)

    def comp_id(self, kind_name: str, inst: int) -> int:
        for ki, k in enumerate(self.kinds):
            if k.name == kind_name:
                return self.comp_base[ki] + inst
        raise KeyError(kind_name)

    # ------------------------------------------------------------------
    def _flat(self, seg: dict) -> jax.Array:
        """Flat global view (ordered by kind => global port id) of a
        per-kind segment dict."""
        parts = [seg[k.name] for k in self.kinds]
        if not parts:
            return jnp.zeros((0,), jnp.int32)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def flat_in_cnt(self, s: SimState) -> jax.Array:
        return self._flat(s.in_cnt)

    def flat_out_cnt(self, s: SimState) -> jax.Array:
        return self._flat(s.out_cnt)

    def copy_state(self, s: SimState) -> SimState:
        """Deep-copy a state so the original survives a donating ``run()``."""
        return jax.tree.map(jnp.copy, s)

    # ------------------------------------------------------------------
    def init_state(self) -> SimState:
        cap, w = self.cap_phys, MSG_WORDS
        next_tick = []
        for k in self.kinds:
            t0 = INF if k.start_asleep else 0.0
            next_tick.append(jnp.full((k.n_instances,), t0, jnp.float32))
        seg = lambda shape_fn: {kc.name: shape_fn(kc) for kc in self._kc}
        zeros_np = lambda kc: jnp.zeros((kc.np_k,), jnp.int32)
        zeros_buf = lambda kc: jnp.zeros((kc.np_k, cap, w), jnp.int32)
        # copy user-supplied init pytrees: donation must never delete (or
        # double-donate aliases of) the builder's arrays
        comp_state = jax.tree.map(
            jnp.copy, {k.name: k.init_state for k in self.kinds})
        return SimState(
            time=jnp.float32(0.0),
            next_tick=(jnp.concatenate(next_tick) if next_tick
                       else jnp.zeros((0,), jnp.float32)),
            conn_wake=jnp.full((self.n_conn,), INF),
            comp_state=comp_state,
            in_buf=seg(zeros_buf), in_head=seg(zeros_np), in_cnt=seg(zeros_np),
            out_buf=seg(zeros_buf), out_head=seg(zeros_np),
            out_cnt=seg(zeros_np),
            rr=jnp.zeros((self.n_conn,), jnp.int32),
            stats=Stats.zero(self.n_comp),
            # min 1 row: zero-sized arrays break shard_map sharding (pdes)
            buf_samples=jnp.zeros((max(self.max_samples, 1), self.n_ports_g),
                                  jnp.int32),
            sample_idx=jnp.int32(0),
            next_sample=jnp.float32(self.sample_period if self.sample_period
                                    else jnp.inf),
        )

    def _port_min_to_comp(self, wake_port):
        """Per-port wake times [PG] -> per-component wake times [NC] by a
        min over each component's (contiguous) ports — static reshapes, no
        scatter."""
        if not self.kinds:
            return jnp.zeros((0,), jnp.float32)
        parts = [
            jnp.min(wake_port[kc.pb:kc.pb + kc.np_k].reshape(kc.n, kc.p),
                    axis=1)
            for kc in self._kc]
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    # ------------------------------------------------------------------
    # Delivery phase: round-robin arbitrated crossbar per connection.
    #
    # Scatter-free: on CPU XLA a scatter costs two orders of magnitude more
    # than the equivalent take/one-hot arithmetic at these array sizes.
    # Connection membership is static, so source-side pops are static takes
    # through ``slot_of_port``; destination-side state is computed *per
    # port* — round-robin arbitration admits at most one winner per
    # destination port per connection, so a [C*M, PG] one-hot reduces
    # exactly to each port's winning slot, and pushes become masked selects
    # on each kind's segment.  A message's dst must be a port of its
    # serving connection (the crossbar contract; arbitration cannot see
    # across connections — the previous scatter formulation corrupted
    # cross-connection collisions just the same, via double in_cnt adds).
    # Traced params (SimParams) enter this phase as broadcast operands only:
    # per-connection latency is repeated over the (static) member axis and
    # per-kind periods over each kind's (static) port count — both are
    # shape-preserving broadcasts XLA folds to constants when the params are
    # the build-time defaults, keeping the params=None path bit- and
    # schedule-identical to the pre-params engine.
    def _deliver(self, s: SimState, P: SimParams, t, active, wake1):
        if not self.kinds:
            return s, jnp.zeros((0,), jnp.float32)
        c = self.c
        lat_f = jnp.repeat(P.conn_latency, self.max_m)            # [C*M]
        pp = [jnp.repeat(P.periods[kc.name], kc.p) for kc in self._kc]
        port_period = pp[0] if len(pp) == 1 else jnp.concatenate(pp)  # [PG]
        C, M, PG = self.n_conn, self.max_m, self.n_ports_g
        CM = C * M
        mps, valid = self._mps_np, jnp.asarray(self._valid_np)   # [C, M]
        # flat views of the per-port arrays (cheap concats at these sizes)
        in_head_f, in_cnt_f = self._flat(s.in_head), self._flat(s.in_cnt)
        out_head_f, out_cnt_f = self._flat(s.out_head), self._flat(s.out_cnt)
        out_buf_f = self._flat(s.out_buf)

        have = (out_cnt_f[mps] > 0) & valid & active[:, None]
        head_ix = out_head_f[mps]                        # [C, M]
        head = out_buf_f[self._mps_j, head_ix]           # [C, M, W]
        dst = head[:, :, W_DST]
        dsts = jnp.clip(dst, 0, PG - 1)
        OH0 = dsts.reshape(CM)[:, None] == self._apg     # [CM, PG] one-hot
        space_port = in_cnt_f < c["caps"]                # [PG]
        space = jnp.any(OH0 & space_port[None, :], axis=1).reshape(C, M)
        req = have & space & (dst >= 0)
        prio = (self._am[None, :] - s.rr[:, None]) % M
        # m loses if some m2 requests the same destination with lower prio.
        beats = (req[:, None, :] & (dst[:, :, None] == dst[:, None, :])
                 & (prio[:, None, :] < prio[:, :, None]))
        win = req & ~jnp.any(beats, axis=2)              # [C, M]
        win_f = win.reshape(CM)
        OHwin = OH0 & win_f[:, None]                     # [CM, PG]

        # per destination port: did it receive, and from which member slot
        got = jnp.any(OHwin, axis=0)                     # [PG]
        wslot = jnp.sum(OHwin * self._acm[:, None], axis=0)       # [PG]
        arrive = t + lat_f                               # [CM]
        msg_f = head.reshape(CM, MSG_WORDS).at[:, W_TIME].set(f2i(arrive))
        msg_port = msg_f[wslot]                          # [PG, W]
        arr_port = jnp.where(got, arrive[wslot], INF)    # [PG]
        t_port = (in_head_f + in_cnt_f) % self.cap_phys
        capOH = (t_port[:, None] == self._acap) & got[:, None]    # [PG, CAP]
        goti = got.astype(jnp.int32)

        # source-side pops: static take (each port has one member slot)
        win_pad = jnp.concatenate([win_f, jnp.zeros((1,), bool)])
        dec = win_pad[self._slot_of_port].astype(jnp.int32)       # [PG]
        full_before_out = out_cnt_f == c["caps"]

        # Rule 1: arrival wakes the destination; rule 2 / backprop forward
        # half: freed source out-buffer wakes its owner.  Both computed per
        # port, then min-reduced onto components (ports are owner-major).
        freed_port = (dec > 0) & full_before_out
        wake_port = jnp.minimum(
            _align_at_or_after(arr_port, port_period),
            jnp.where(freed_port, _align_after(t, port_period), INF))
        wake_comp = self._port_min_to_comp(wake_port)

        # per-kind segment updates (pure where/add on each segment slice)
        out_cnt_seg, out_head_seg = dict(s.out_cnt), dict(s.out_head)
        in_buf_seg, in_cnt_seg = dict(s.in_buf), dict(s.in_cnt)
        for kc in self._kc:
            sl = slice(kc.pb, kc.pb + kc.np_k)
            out_cnt_seg[kc.name] = s.out_cnt[kc.name] - dec[sl]
            out_head_seg[kc.name] = (s.out_head[kc.name]
                                     + dec[sl]) % self.cap_phys
            in_cnt_seg[kc.name] = s.in_cnt[kc.name] + goti[sl]
            in_buf_seg[kc.name] = jnp.where(
                capOH[sl][:, :, None], msg_port[sl][:, None, :],
                s.in_buf[kc.name])

        # round-robin pointer: advance past the last-served winner
        gp = jnp.where(win, prio, -1)
        any_win = jnp.any(win, axis=1)
        last = jnp.argmax(gp, axis=1).astype(jnp.int32)
        rr = jnp.where(any_win, (last + 1) % M, s.rr)

        # connection self-scheduling: if it delivered and work remains, wake
        # next cycle; otherwise sleep (backprop / sends will wake it).
        out_cnt_f2 = out_cnt_f - dec
        pending = jnp.any(valid & (out_cnt_f2[mps] > 0), axis=1)
        nw = jnp.where(any_win & pending, wake1, INF)
        conn_wake = jnp.where(active, nw, s.conn_wake)

        delivered = jnp.sum(win_f.astype(jnp.int32))
        s = dataclasses.replace(
            s, in_buf=in_buf_seg, in_cnt=in_cnt_seg,
            out_cnt=out_cnt_seg, out_head=out_head_seg, rr=rr,
            conn_wake=conn_wake,
            stats=dataclasses.replace(s.stats,
                                      delivered=s.stats.delivered + delivered))
        return s, wake_comp

    # ------------------------------------------------------------------
    # Tick phase: vmap each kind's tick_fn over its instances; with the
    # segmented layout each kind reads/writes only its own segment.
    def _tick_kinds(self, s: SimState, P: SimParams, t, wake1):
        next_tick = s.next_tick
        comp_state = dict(s.comp_state)
        in_buf, in_head, in_cnt = dict(s.in_buf), dict(s.in_head), dict(s.in_cnt)
        out_buf, out_head, out_cnt = (dict(s.out_buf), dict(s.out_head),
                                      dict(s.out_cnt))
        total_ticks = jnp.int32(0)
        total_prog = jnp.int32(0)
        busy = s.stats.busy
        tf = jnp.asarray(t, jnp.float32)
        wake_p_segs = {}           # kind -> [n*p] bool: port wants its conn

        for ki, kind in enumerate(self.kinds):
            kc = self._kc[ki]
            n, p, name = kc.n, kc.p, kc.name
            periods_k = P.periods[name]
            if self.naive:
                r = jnp.remainder(t, periods_k)
                mask = (jnp.abs(r) < EPS) | (jnp.abs(r - periods_k) < EPS)
            else:
                mask = next_tick[kc.csl] <= t + EPS
            if P.inst_mask is not None:
                # family activity mask: masked-off instances never tick
                # (and therefore never count toward ticks/progress/busy)
                mask = mask & P.inst_mask[name]

            sh = lambda a: a.reshape(n, p, *a.shape[1:])
            # kind params are closed over, not vmapped: every instance of a
            # kind sees the same (possibly traced) parameter pytree
            kp = P.kind.get(name, {})
            wants_params = kind.params is not None

            def one(st_i, ib, ih, ic, ob, oh, oc, cp, g, pe, kind=kind,
                    kp=kp, wants_params=wants_params):
                ports = Ports(ib, ih, ic, ob, oh, oc, cp, g, pe, tf)
                out = (kind.tick_fn(st_i, ports, tf, kp) if wants_params
                       else kind.tick_fn(st_i, ports, tf))
                st2, ports2, res = normalize_tick_output(out)
                return (st2, ports2.in_buf, ports2.in_head, ports2.in_cnt,
                        ports2.out_buf, ports2.out_head, ports2.out_cnt,
                        res.progress, res.next_time)

            (st2, ib2, ih2, ic2, ob2, oh2, oc2, prog, nxt) = jax.vmap(one)(
                comp_state[name], sh(in_buf[name]), sh(in_head[name]),
                sh(in_cnt[name]), sh(out_buf[name]), sh(out_head[name]),
                sh(out_cnt[name]), kc.caps, kc.gid, kc.peer)

            def sel(new, old, m=mask):
                mm = m.reshape(m.shape + (1,) * (new.ndim - 1))
                return jnp.where(mm, new, old)

            comp_state[name] = jax.tree.map(
                lambda a, b: sel(a, b), st2, comp_state[name])
            fl = lambda a: a.reshape(n * p, *a.shape[2:])
            pmask = jnp.repeat(mask, p)

            def psel(new, old):
                mm = pmask.reshape(pmask.shape + (1,) * (new.ndim - 1))
                return jnp.where(mm, new, old)

            ic_old, oc_old = in_cnt[name], out_cnt[name]
            in_buf[name] = psel(fl(ib2), in_buf[name])
            in_head[name] = psel(fl(ih2), in_head[name])
            in_cnt[name] = psel(fl(ic2), in_cnt[name])
            out_buf[name] = psel(fl(ob2), out_buf[name])
            out_head[name] = psel(fl(oh2), out_head[name])
            out_cnt[name] = psel(fl(oc2), out_cnt[name])

            prog = prog & mask
            if not self.naive:
                # Rule 3: progress => next cycle; no progress => sleep.
                base = jnp.where(prog, _align_after(t, periods_k), INF)
                custom = jnp.where(nxt > -0.5, jnp.maximum(nxt, t + EPS), base)
                # In-flight arrivals: a ticked component must not sleep past
                # the ready time of a message already in its buffers (rule 1
                # for arrivals whose delivery preceded this tick).  Ready-now
                # messages do NOT re-wake — unblocking is backprop's job.
                hb = in_buf[name][:, :, W_TIME]             # [n*p, CAP]
                hOH = in_head[name][:, None] == self._acap  # one-hot gather
                hr = i2f(jnp.sum(hb * hOH.astype(jnp.int32), axis=1))
                pend = (in_cnt[name] > 0) & (hr > t + EPS)
                w = jnp.where(pend, hr, INF).reshape(n, p)
                arr = _align_at_or_after(jnp.min(w, axis=1), periods_k)
                custom = jnp.minimum(custom, arr)
                next_tick = next_tick.at[kc.csl].set(
                    jnp.where(mask, custom, next_tick[kc.csl]))

            # Availability Backpropagation (backward half): incoming buffer
            # full->not-full wakes the serving connection; any new send wakes
            # the connection too.
            ic_new, oc_new = in_cnt[name], out_cnt[name]
            in_freed = (ic_old == kc.caps_f) & (ic_new < kc.caps_f)
            wake_p_segs[name] = in_freed | (oc_new > oc_old)

            total_ticks += jnp.sum(mask.astype(jnp.int32))
            total_prog += jnp.sum(prog.astype(jnp.int32))
            busy = busy.at[kc.csl].add(prog.astype(jnp.int32))

        # a connection wakes iff any of its (static) member ports asked —
        # static take through the member matrix instead of a scatter-min
        if self.kinds:
            wake_p_f = self._flat(wake_p_segs)
            wake_pad = jnp.concatenate([wake_p_f, jnp.zeros((1,), bool)])
            conn_asked = jnp.any(wake_pad[self._member_sent_np], axis=1)
            wake_conn = jnp.where(conn_asked, wake1, INF)
        else:
            wake_conn = jnp.full((self.n_conn,), INF)

        stats = dataclasses.replace(
            s.stats, ticks=s.stats.ticks + total_ticks,
            progress_ticks=s.stats.progress_ticks + total_prog, busy=busy)
        s = dataclasses.replace(
            s, next_tick=next_tick, comp_state=comp_state, in_buf=in_buf,
            in_head=in_head, in_cnt=in_cnt, out_buf=out_buf,
            out_head=out_head, out_cnt=out_cnt, stats=stats)
        return s, wake_conn

    # ------------------------------------------------------------------
    def _epoch(self, s: SimState, P: SimParams):
        if self.naive:
            t = s.time  # process the current cycle, then advance by one
            active = jnp.ones((self.n_conn,), bool)
        else:
            t = jnp.minimum(jnp.min(s.next_tick) if self.n_comp else INF,
                            jnp.min(s.conn_wake))
            if self.max_samples:
                t = jnp.minimum(t, s.next_sample)
            active = s.conn_wake <= t + EPS
        if P.conn_mask is not None:
            # family activity mask: masked-off connections never deliver
            active = active & P.conn_mask

        wake1 = _align_after(t, 1.0)          # shared next-cycle wake point
        s = dataclasses.replace(s, time=t)
        s, wake_comp = self._deliver(s, P, t, active, wake1)
        s, wake_conn = self._tick_kinds(s, P, t, wake1)
        next_tick = jnp.minimum(s.next_tick, wake_comp)
        conn_wake = jnp.minimum(s.conn_wake, wake_conn)
        # Masked-off rows are pinned to +inf by broadcast selects so the
        # next-event min never schedules them — the mask's only entry
        # points into the wake reductions (no gathers/scatters involved).
        if P.inst_mask is not None:
            next_tick = jnp.where(self._flat_inst_mask(P.inst_mask),
                                  next_tick, INF)
        if P.conn_mask is not None:
            conn_wake = jnp.where(P.conn_mask, conn_wake, INF)
        s = dataclasses.replace(
            s, next_tick=next_tick, conn_wake=conn_wake,
            stats=dataclasses.replace(s.stats, epochs=s.stats.epochs + 1))
        if self.max_samples:
            do = s.next_sample <= t + EPS
            row = s.sample_idx % self.max_samples
            s = dataclasses.replace(
                s,
                buf_samples=jnp.where(
                    do, s.buf_samples.at[row].set(self._flat(s.in_cnt)),
                    s.buf_samples),
                sample_idx=s.sample_idx + do.astype(jnp.int32),
                next_sample=jnp.where(do, s.next_sample + self.sample_period,
                                      s.next_sample))
        if self.naive:
            s = dataclasses.replace(s, time=t + 1.0)
        return s

    def _next_event(self, s: SimState):
        t = jnp.min(s.next_tick) if self.n_comp else INF
        t = jnp.minimum(t, jnp.min(s.conn_wake))
        if self.max_samples:
            t = jnp.minimum(t, s.next_sample)
        return t

    def _live(self, s: SimState, until, max_epochs):
        """Liveness predicate of the hot loop: events remain before the
        horizon AND the epoch budget is not exhausted.  ``until`` and
        ``max_epochs`` are ordinary traced operands, so ``repro.dse`` can
        vmap this per lane (per-lane horizons) and poll it cheaply between
        rounds without recompiling anything."""
        if self.naive:
            more = s.time <= until + EPS
        else:
            more = self._next_event(s) <= until + EPS
        return more & (s.stats.epochs < max_epochs)

    def _run(self, s: SimState, until, max_epochs,
             params: SimParams | None = None):
        P = self._dp if params is None else params
        until = jnp.asarray(until, jnp.float32)
        max_epochs = jnp.asarray(max_epochs, jnp.int32)
        cond = lambda s: self._live(s, until, max_epochs)
        if self.super_epoch <= 1:
            return jax.lax.while_loop(cond, lambda s: self._epoch(s, P), s)

        # Super-epoch fusion: K epochs per while iteration.  Each inner step
        # re-checks liveness and is an exact no-op (lax.cond identity) once
        # the horizon/epoch budget is reached, so results are bit-identical
        # to the K=1 path while the loop condition round-trip is amortized
        # K-fold and XLA can fuse across the unrolled steps.
        def body(s):
            def step(s, _):
                s = jax.lax.cond(self._live(s, until, max_epochs),
                                 lambda x: self._epoch(x, P), lambda x: x, s)
                return s, None
            s, _ = jax.lax.scan(step, s, None, length=self.super_epoch,
                                unroll=True)
            return s

        return jax.lax.while_loop(cond, body, s)

    def run(self, state: SimState, until: float,
            max_epochs: int = 2_000_000,
            params: SimParams | None = None) -> SimState:
        """Advance the simulation to virtual time ``until`` (cycles).

        When the simulation was built with ``donate=True`` (the default),
        ``state``'s buffers are donated to the jitted loop and must not be
        reused afterwards — keep using the *returned* state, or pass
        ``copy_state(state)`` if the input must survive.

        ``until`` and ``max_epochs`` are *traced* operands: changing
        either re-runs the same compiled loop (no recompile), and batched
        runs (``repro.dse``) may pass per-lane values so every lane
        freezes at its own horizon / epoch budget.

        ``params`` (optional) overrides the traced timing/model parameters
        for this run (see :class:`SimParams` / ``default_params()``); its
        leaves are never donated.  ``None`` runs the build-time defaults."""
        assert until < 2 ** 24, "float32 cycle precision bound (DESIGN.md)"
        if self.donate:
            check_not_consumed(state)
        return self._run_jit(state, until, max_epochs=max_epochs,
                             params=params)
