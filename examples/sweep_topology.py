"""Structural design-space exploration: sweep the *shape* of the memsys
hierarchy without recompiling.

Classically every core count is its own build + jit compile (gem5-style
one-compile-per-config).  Here the ``shape.core`` axis lowers to a
**topology family** (DSE.md): one padded build at 8 cores plus traced
activity masks, so the whole 4 shapes x 3 cache points grid is ONE
compiled vmapped simulation — and each masked lane is bit-identical on
active rows to an unpadded build of its shape
(``tests/dse/test_structural.py``).

Prints the tidy result grid and the throughput-vs-area Pareto front
(DRAM reads served per cycle against active core count — the classic
"how many cores are worth wiring" question).

Run:  PYTHONPATH=src python examples/sweep_topology.py
"""
from repro.dse import SweepSpec, format_table, pareto_front, run_sweep
from repro.sims.memsys import build_family, finish_stats

AXES = {
    "shape.core": [1, 2, 4, 8],                     # topology shape (masked)
    "kind.l1.extra_hit_rate": [0.0, 0.4, 0.8],      # L1 boost (cache "size")
}


def build_fn(shape):
    # called once, at the family maximum (shape={"core": 8})
    return build_family(shape=shape, pattern="mixed", n_reqs=32,
                        donate=True)


def extract(sim, s):
    fs = finish_stats(sim, s)
    return {"virtual_time": fs["virtual_time"],
            "reads_done": fs["reads_done"],
            "reads_per_kcycle": 1e3 * fs["reads_done"]
            / max(fs["virtual_time"], 1.0),
            "done": fs["remaining"] == 0}


def main():
    spec = SweepSpec.grid(AXES)
    rows = run_sweep(build_fn, spec, until=100000.0, extract=extract)
    assert all(r["done"] for r in rows), "raise `until`"
    print(f"== all {len(rows)} design points (one compile, one family) ==")
    print(format_table(rows))

    front = pareto_front(rows, {
        "reads_per_kcycle": "max",       # memory throughput...
        "shape.core": "min",             # ...from the fewest cores
    })
    print(f"\n== Pareto front: throughput vs core budget "
          f"({len(front)}/{len(rows)} points) ==")
    print(format_table(front))


if __name__ == "__main__":
    main()
