"""Closed-loop design-space search of the memsys memory hierarchy.

Instead of sweeping the full 96-point grid (crossbar latency x L1
hit-rate boost x DRAM period), a seeded ``SuccessiveHalving`` search
runs *every* config a short horizon, promotes the top third to a 3x
longer one, and so on until only a handful reach the full horizon —
finding the minimum-completion-time config for a fraction of the
exhaustive simulated-cycle budget.  Every round executes as one
vmapped, chunk-laddered sweep (per-lane horizons; zero recompiles after
warmup).  Promotions are *warm*: a promoted config resumes from its
frozen rung-end ``SimState`` instead of replaying from cycle 0, so the
budget counts only horizon increments (DSE.md "Warm-state
promotions").  The search is resumable: its ``SearchState`` is plain
JSON, and ``save_search``/``load_search`` extend the snapshot with the
frozen rung states so a resumed search's budget matches bit-exactly.

The objective is ``est_finish`` — estimated completion time
``virtual_time * total / done``, which ranks configs by throughput
mid-flight and equals the true completion time once a config drains.

Run:  PYTHONPATH=src python examples/search_memsys.py
"""
import numpy as np

from repro.dse import (SuccessiveHalving, SweepSpec, format_table,
                       memoize_build, run_search, run_sweep)
from repro.sims.memsys import build

AXES = {
    "conn_latency[-1]": [10.0, 25.0, 40.0, 70.0],   # DRAM crossbar latency
    "kind.l1.extra_hit_rate": [0.0, 0.15, 0.3, 0.45, 0.6, 0.8],
    "period.dram": [1.0, 2.0, 3.0, 4.0],            # DRAM service interval
}
MAX_H = 5600.0        # full horizon: every config drains by here
ETA = 3


def main():
    build_fn = memoize_build(
        lambda: build(n_cores=8, pattern="mixed", n_reqs=24, donate=True,
                      super_epoch=4))
    sim, st = build_fn()
    total = int(np.sum(np.asarray(st.comp_state["core"]["remaining"])))

    def extract(sim, s):
        rem = int(np.sum(np.asarray(s.comp_state["core"]["remaining"])))
        vt = float(s.time)
        return {"virtual_time": vt, "remaining": rem,
                "est_finish": vt * total / max(total - rem, 1)}

    pool = SweepSpec.grid(AXES, validate_for=sim)
    # the cycle budget is a hard cap on simulated-cycle spend — the
    # search stops early (keeping its best-so-far) if it ever hits it
    driver = SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                               min_horizon=MAX_H / ETA**3, eta=ETA, seed=0,
                               cycle_budget=60_000.0)
    res = run_search(build_fn, driver, extract=extract)

    best = {k: res.best[k] for k in
            list(AXES) + ["est_finish", "until", "round"]}
    print(f"== best of {len(pool)} configs after {res.rounds} rounds / "
          f"{len(res.rows)} trials ==")
    print(format_table([best]))

    # what the search saved: the exhaustive sweep of the same grid
    rows = run_sweep(build_fn, pool, until=MAX_H, extract=extract)
    exhaustive = sum(r["virtual_time"] for r in rows)
    opt = min(r["est_finish"] for r in rows)
    print(f"\nsearch budget: {res.budget:.0f} simulated cycles "
          f"({100 * res.budget / exhaustive:.1f}% of the exhaustive "
          f"{exhaustive:.0f}); objective {res.best['est_finish']:.0f} vs "
          f"exhaustive optimum {opt:.0f}")

    # runtime-vs-cache-budget front over the configs the search actually
    # finished (full-horizon trials): the cheapest cache at each speed
    from repro.dse import pareto_front
    finals = [t for t in res.rows if t["until"] == MAX_H]
    front = pareto_front(finals, {"est_finish": "min",
                                  "kind.l1.extra_hit_rate": "min"})
    print(f"\n== front over the {len(finals)} fully-run configs ==")
    print(format_table([{k: r[k] for k in list(AXES) + ["est_finish"]}
                        for r in front]))


if __name__ == "__main__":
    main()
