"""Serving example: train a byte-level LM briefly, then serve batched
requests — prefill builds the KV cache, decode streams tokens greedily.

  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data import ByteTokenizer, DataPipeline  # noqa: E402
from repro.models import transformer as tfm  # noqa: E402
from repro.train.loop import LoopConfig, train  # noqa: E402
from repro.train.step import TrainHParams  # noqa: E402

CORPUS = ("the quick brown fox jumps over the lazy dog. " * 3000)


def main():
    cfg = dataclasses.replace(
        get_config("stablelm-1.6b"), n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=512, vocab=256)
    data = DataPipeline.from_text(cfg, CORPUS, batch=8, seq=96)
    params, _, _ = train(cfg, data,
                         LoopConfig(steps=150, ckpt_every=1000,
                                    ckpt_dir="runs/serve_ckpt",
                                    log_every=50),
                         TrainHParams(lr=3e-3, donate=False))

    tok = ByteTokenizer()
    prompts = ["the quick brown ", "pack my box with ",
               "the lazy ", "five dozen "]
    S0 = max(len(p) for p in prompts)
    ids = jnp.stack([jnp.pad(jnp.asarray(tok.encode(p) % cfg.vocab),
                             (S0 - len(p), 0)) for p in prompts])
    B, T = len(prompts), 24

    logits, pcache, _ = tfm.forward(params, cfg, {"tokens": ids},
                                    mode="prefill")
    cache = tfm.init_cache(cfg, B, S0 + T)
    cache = {k: v.at[:, :, :S0].set(pcache[k].astype(v.dtype))
             for k, v in cache.items()}
    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    outs = [nxt]
    decode = jax.jit(lambda p, c, t, pos: tfm.forward(
        p, cfg, {"tokens": t}, mode="decode", cache=c, positions=pos,
        cache_len=pos + 1)[:2])
    for t in range(S0, S0 + T - 1):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = decode(params, cache, nxt, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(nxt)
    gen = jnp.concatenate(outs, axis=1)
    for p, g in zip(prompts, gen):
        print(f"{p!r} -> {tok.decode(list(g))!r}")


if __name__ == "__main__":
    main()
