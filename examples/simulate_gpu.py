"""Engine showcase: simulate a GPU-like multicore memory system with Smart
Ticking, live AkitaRTM-style monitoring (+ optional HTTP endpoint), buffer-
level bottleneck analysis, and a Daisen trace export.

  PYTHONPATH=src python examples/simulate_gpu.py [--cores 16] [--http 8321]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.daisen import export_db  # noqa: E402
from repro.core.monitor import Monitor  # noqa: E402
from repro.core.tracers import DBTracer, flush_engine_trace  # noqa: E402
from repro.core.tracing import TracingDomain  # noqa: E402
from repro.sims.memsys import build, finish_stats  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cores", type=int, default=16)
    ap.add_argument("--pattern", default="mixed")
    ap.add_argument("--http", type=int, default=None)
    ap.add_argument("--out", default="runs/simulate_gpu")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    dom = TracingDomain("sim")
    db = dom.attach(DBTracer(os.path.join(args.out, "trace.db")))
    sim, st = build(n_cores=args.cores, pattern=args.pattern, n_reqs=256,
                    sample_period=64.0)
    mon = Monitor(sim, st, domain=dom, http_port=args.http)
    with dom.task("simulation", f"memsys/{args.pattern}", "engine"):
        final, hung = mon.run_monitored(until=200000.0, chunk=2000.0)
    stats = finish_stats(sim, final)
    print("\nfinal:", stats)
    print("bottlenecks:", mon.bottleneck_report() or "none (all drained)")
    flush_engine_trace(sim, final, db)
    db.flush()
    html = export_db(db, os.path.join(args.out, "trace.html"),
                     title=f"memsys {args.pattern} x{args.cores}")
    db.close()
    mon.close()
    print(f"Daisen-lite trace: {html}")


if __name__ == "__main__":
    main()
