"""Onira example (paper §5.1): the in-order RISC-V timing model — CPI per
microbenchmark vs the analytic pipeline reference, plus the MLP sweep.

  PYTHONPATH=src python examples/onira_riscv.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sims.onira import (analytic_cpi, run_microbenches,  # noqa: E402
                              run_mlp_sweep)


def main():
    print(f"{'bench':>10s} {'cpi':>7s} {'ref':>7s} {'err%':>6s}")
    for name, r in run_microbenches().items():
        ref = analytic_cpi(name)
        print(f"{name:>10s} {r['cpi']:>7.3f} {ref:>7.3f} "
              f"{abs(r['cpi']-ref)/ref*100:>5.1f}%")
    print("\nMLP sweep (CPI vs independent loads — paper Fig 13a):")
    for n, cpi in run_mlp_sweep().items():
        print(f"  N={n:>2d}: CPI={cpi:.2f} " + "#" * int(cpi * 4))


if __name__ == "__main__":
    main()
