"""Quickstart: end-to-end training of a small LM on CPU with the full
substrate — data pipeline, AdamW, checkpoints (async, keep-k, resumable),
fault-tolerant loop, and Akita-style task tracing with a Daisen export.

  PYTHONPATH=src python examples/quickstart.py [--steps 200] [--preset 100m]

The default preset is CPU-sized (~3M params); --preset 100m builds the
~100M-parameter configuration (same code path, longer wall time).
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config  # noqa: E402
from repro.core.daisen import export_db  # noqa: E402
from repro.core.tracers import DBTracer  # noqa: E402
from repro.core.tracing import TracingDomain  # noqa: E402
from repro.data import DataPipeline  # noqa: E402
from repro.train.loop import LoopConfig, train  # noqa: E402
from repro.train.step import TrainHParams  # noqa: E402

CORPUS = ("the quick brown fox jumps over the lazy dog. "
          "pack my box with five dozen liquor jugs. ") * 2000


def preset(name: str):
    base = get_config("stablelm-1.6b")
    if name == "tiny":
        return dataclasses.replace(base, n_layers=4, d_model=128, n_heads=4,
                                   n_kv_heads=4, head_dim=32, d_ff=512,
                                   vocab=256), 8, 128
    if name == "100m":
        return dataclasses.replace(base, n_layers=12, d_model=768, n_heads=12,
                                   n_kv_heads=12, head_dim=64, d_ff=3072,
                                   vocab=256), 8, 256
    raise SystemExit(f"unknown preset {name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--out", default="runs/quickstart")
    args = ap.parse_args()

    cfg, batch, seq = preset(args.preset)
    data = DataPipeline.from_text(cfg, CORPUS, batch=batch, seq=seq)
    dom = TracingDomain("quickstart")
    os.makedirs(args.out, exist_ok=True)
    db = dom.attach(DBTracer(os.path.join(args.out, "trace.db")))

    params, _, hist = train(
        cfg, data,
        LoopConfig(steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                   ckpt_dir=os.path.join(args.out, "ckpt"), log_every=10),
        TrainHParams(lr=3e-3, donate=False), domain=dom)
    db.flush()
    html = export_db(db, os.path.join(args.out, "trace.html"),
                     title="quickstart training run")
    db.close()
    print(f"\nfinal loss {hist[-1]['loss']:.3f} "
          f"(start {hist[0]['loss']:.3f}) — trace at {html}")


if __name__ == "__main__":
    main()
