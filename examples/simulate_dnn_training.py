"""TrioSim example (paper §5.2): predict training step times for the
assigned architectures across DP/TP/PP plans — the engine and the training
framework meeting in one tool.

  PYTHONPATH=src python examples/simulate_dnn_training.py [--arch ...]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config  # noqa: E402
from repro.sims.opgraph import HW, analytic_step_us  # noqa: E402
from repro.sims.triosim import simulate_step  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--layers", type=int, default=24,
                    help="override depth to keep trace size CPU-friendly")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    hw = HW()
    print(f"{args.arch} ({cfg.param_count()/1e9:.2f}B params), "
          f"batch 16 x seq 1024, {hw.flops/1e12:.0f} TF/s devices\n")
    print(f"{'plan':>16s} {'sim_ms':>9s} {'analytic_ms':>12s} {'ratio':>6s}")
    for dp, tp, pp in [(4, 1, 1), (1, 4, 1), (1, 1, 4), (2, 2, 1),
                       (1, 2, 2)]:
        r = simulate_step(cfg, batch=16, seq=1024, dp=dp, tp=tp, pp=pp,
                          micro=4, hw=hw)
        a = analytic_step_us(cfg, 16, 1024, dp, tp, pp, 4, hw)
        print(f"dp{dp} tp{tp} pp{pp:>2d} {r['step_us']/1e3:>11.1f} "
              f"{a/1e3:>12.1f} {r['step_us']/a:>6.2f}")


if __name__ == "__main__":
    main()
