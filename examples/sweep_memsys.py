"""Design-space exploration of the memsys GPU memory hierarchy.

A 3-axis grid — crossbar/DRAM latency (traced), forced L1 hit-rate boost
(traced, a stand-in for cache size/associativity), and the engine's
super-epoch fusion width (static build knob) — swept with ``repro.dse``:
each super-epoch group compiles once and all its latency x hit-rate
points run in a single vmapped jitted simulation.

Prints the full tidy results table and the runtime-vs-cache-budget
Pareto front (fastest design at each cache aggressiveness; the memory
latency axis collapses onto its fastest setting).

Run:  PYTHONPATH=src python examples/sweep_memsys.py
"""
from repro.dse import SweepSpec, format_table, pareto_front, run_sweep
from repro.sims.memsys import build, finish_stats

AXES = {
    "conn_latency[-1]": [10.0, 20.0, 40.0, 80.0],   # DRAM crossbar latency
    "kind.l1.extra_hit_rate": [0.0, 0.4, 0.8],      # L1 boost (cache "size")
    "static.super_epoch": [1, 4],                   # perf-only build knob
}


def build_fn(super_epoch=None):
    return build(n_cores=8, pattern="mixed", n_reqs=32,
                 super_epoch=super_epoch, donate=True)


def extract(sim, s):
    fs = finish_stats(sim, s)
    return {"virtual_time": fs["virtual_time"], "hits": fs["hits"],
            "misses": fs["misses"], "done": fs["remaining"] == 0}


def main():
    spec = SweepSpec.grid(AXES)
    rows = run_sweep(build_fn, spec, until=100000.0, extract=extract)
    assert all(r["done"] for r in rows), "raise `until`"
    print(f"== all {len(rows)} design points ==")
    print(format_table(rows))

    # super_epoch never changes results (equivalence invariant) — drop it
    # for the architectural Pareto question.  (Adding the latency axis as
    # a third "max" objective would keep every grid point: on a full grid
    # each latency level is its own trade-off chain.)
    arch = [r for r in rows if r["static.super_epoch"] == 1]
    front = pareto_front(arch, {
        "virtual_time": "min",           # fast...
        "kind.l1.extra_hit_rate": "min"  # ...with the least cache
    })
    print(f"\n== Pareto front: runtime vs cache budget "
          f"({len(front)}/{len(arch)} points) ==")
    print(format_table(front))


if __name__ == "__main__":
    main()
