"""Closed-loop search (`repro.dse.search`): driver contract, successive
halving, batched BO, and the invariants that make search results
trustworthy:

* seeded searches are bit-reproducible, and a `SearchState` serialized
  at any round boundary resumes the *identical* trajectory;
* repeat searches through a memoized build function retrace nothing
  (the tuned ladder and compiled rungs are reused across rounds);
* successive halving finds the exhaustive optimum of a small grid for
  less simulated-cycle budget than the exhaustive sweep;
* `shape.*` family axes are first-class search axes (one family build
  serves every round).
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.dse import (BatchBO, Objective, RandomSearch, SearchState,
                       SuccessiveHalving, SweepSpec, horizon_ladder,
                       memoize_build, run_search, run_sweep, runner_for)
from repro.sims.memsys import build, build_family

MAX_H = 2000.0


@pytest.fixture(scope="module")
def ctx():
    """One memoized small memsys build shared by every search test (the
    point of memoize_build is exactly this reuse)."""
    built = []

    def build_fn():
        built.append(1)
        return build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)

    bf = memoize_build(build_fn)
    sim, st = bf()
    total = int(np.sum(np.asarray(st.comp_state["core"]["remaining"])))

    def extract(sim, s):
        rem = int(np.sum(np.asarray(s.comp_state["core"]["remaining"])))
        vt = float(s.time)
        done = total - rem
        return {"virtual_time": vt, "remaining": rem,
                "est_finish": vt * total / max(done, 1)}

    pool = SweepSpec.grid({"conn_latency[-1]": [10., 20., 30., 40.],
                           "kind.l1.extra_hit_rate": [0.0, 0.4, 0.8]})
    return bf, sim, extract, pool, built


def _sh(pool, **kw):
    args = dict(max_horizon=MAX_H, min_horizon=60.0, eta=3, seed=0)
    args.update(kw)
    return SuccessiveHalving(pool, "est_finish", **args)


# ---------------------------------------------------------------------------
def test_horizon_ladder_geometry():
    assert horizon_ladder(2000.0, rungs=1) == [2000.0]
    lad = horizon_ladder(2700.0, min_horizon=100.0, eta=3)
    assert lad == [100.0, 300.0, 900.0, 2700.0]
    assert horizon_ladder(2000.0, min_horizon=2000.0, eta=3) == [2000.0]
    # rungs= names the count directly
    assert horizon_ladder(800.0, rungs=3, eta=2) == [200.0, 400.0, 800.0]


def test_successive_halving_finds_exhaustive_optimum_cheaper(ctx):
    bf, sim, extract, pool, _ = ctx
    res = run_search(bf, _sh(pool), extract=extract)
    rows = run_sweep(bf, pool, until=MAX_H, extract=extract)
    opt = min(r["est_finish"] for r in rows)
    exhaustive_budget = sum(r["virtual_time"] for r in rows)
    assert res.best["est_finish"] == opt          # found the true optimum
    assert res.best["until"] == MAX_H             # ...at the full horizon
    assert res.budget < exhaustive_budget         # ...for less spend
    assert len(res.rows) < 3 * len(pool)          # and far fewer trials
    # budget accounting matches the recorded trials exactly: each trial
    # records its newly simulated cycles, and warm promotion makes the
    # total strictly less than the sum of trial virtual times (promoted
    # configs no longer replay their earlier rungs)
    assert res.budget == pytest.approx(sum(t["cycles"] for t in res.rows))
    assert res.budget < sum(t["virtual_time"] for t in res.rows)
    # promotion shrinks rung populations by ~eta
    per_round = {}
    for t in res.rows:
        per_round[t["round"]] = per_round.get(t["round"], 0) + 1
    sizes = [per_round[r] for r in sorted(per_round)]
    assert sizes[0] == len(pool) and sizes == sorted(sizes, reverse=True)
    assert sizes[1] == math.ceil(sizes[0] / 3)


def test_search_is_bit_reproducible_per_seed(ctx):
    bf, sim, extract, pool, _ = ctx
    r1 = run_search(bf, _sh(pool), extract=extract)
    r2 = run_search(bf, _sh(pool), extract=extract)
    assert r1.rows == r2.rows
    assert r1.best == r2.best and r1.budget == r2.budget


def test_search_state_resumes_identical_trajectory(ctx):
    """JSON-only resume: with replay promotion (warm=False) the bare
    ``SearchState`` string is the whole search — resuming from any round
    boundary replays the identical trajectory *and* budget.  (A warm
    search resumed from JSON alone also produces identical rows but
    re-pays its current rungs; carrying the rung states across a resume
    needs the checkpoint path — tests/dse/test_warm_resume.py.)"""
    bf, sim, extract, pool, _ = ctx
    snaps = []
    full = run_search(bf, _sh(pool, warm=False), extract=extract,
                      callback=lambda d: snaps.append(d.state.to_json()))
    assert len(snaps) == full.rounds
    for k in range(len(snaps) - 1):       # resume from every boundary
        state = SearchState.from_json(snaps[k])
        assert state.round == k + 1
        resumed = run_search(bf, _sh(pool, warm=False, state=state),
                             extract=extract)
        assert resumed.rows == full.rows
        assert resumed.best == full.best
        assert resumed.budget == full.budget
        assert resumed.rounds == full.rounds - (k + 1)


def test_repeat_search_reuses_builds_and_retraces_nothing(ctx):
    bf, sim, extract, pool, built = ctx
    run_search(bf, _sh(pool), extract=extract)          # warmup search
    runner = runner_for(sim)
    builds0, traces0 = len(built), runner.trace_count
    res = run_search(bf, _sh(pool), extract=extract)
    assert len(built) == builds0                        # memoized build
    assert runner.trace_count == traces0, (
        f"{runner.trace_count - traces0} retraces in a repeat search")
    assert res.best is not None


def test_bracketed_halving_asks_mixed_horizons(ctx):
    bf, sim, extract, pool, _ = ctx
    drv = _sh(pool, brackets=2)
    pts, us = drv.ask()
    assert len(pts) == len(pool)            # both brackets in one batch
    assert len(set(us)) == 2                # ...at two different horizons
    lad = drv.horizons
    assert set(us) == {lad[0], lad[1]}
    # the full bracketed search still lands on a full-horizon best
    drv2 = _sh(pool, brackets=2)
    res = run_search(bf, drv2, extract=extract)
    assert res.best["until"] == MAX_H
    assert res.front and res.front[0]["until"] == MAX_H


def test_cycle_budget_hard_stops_the_search(ctx):
    bf, sim, extract, pool, _ = ctx
    free = run_search(bf, _sh(pool), extract=extract)
    cap = free.budget * 0.4
    res = run_search(bf, _sh(pool, cycle_budget=cap), extract=extract)
    assert res.rounds < free.rounds
    # budget may overshoot by at most the round that crossed the cap
    assert res.budget >= cap or res.rounds == free.rounds
    assert res.best is not None             # falls back to best-so-far


def test_shape_axes_are_first_class_search_axes():
    built = []

    def build_fn(shape=None):
        built.append(dict(shape))
        return build_family(shape=shape, pattern="mixed", n_reqs=6,
                            donate=True)

    bf = memoize_build(build_fn)

    pool = SweepSpec.grid({"shape.core": [1, 2, 4],
                           "conn_latency[-1]": [10.0, 30.0]})
    drv = SuccessiveHalving(pool, "virtual_time", max_horizon=MAX_H,
                            min_horizon=200.0, eta=2, seed=0)
    res = run_search(bf, drv, extract=None)
    assert len(built) == 1                  # one family serves every round
    assert built[0] == {"core": 4}          # ...built at the pool maximum
    assert res.best["until"] == MAX_H
    assert res.best["shape.core"] in (1, 2, 4)
    r1 = run_search(bf, SuccessiveHalving(
        pool, "virtual_time", max_horizon=MAX_H, min_horizon=200.0,
        eta=2, seed=0))
    assert r1.rows == res.rows              # reproducible, still one build
    assert len(built) == 1


def test_memoize_build_family_growth_and_reuse():
    @dataclasses.dataclass
    class Fam:
        shape_max: dict

    calls = []

    def build_fn(shape=None, **kw):
        calls.append(dict(shape))
        return Fam(dict(shape))

    bf = memoize_build(build_fn)
    f1 = bf(shape={"core": 2})
    assert bf(shape={"core": 1}) is f1      # covered: reuse
    f2 = bf(shape={"core": 4})              # grow: rebuild at the union
    assert f2 is not f1 and f2.shape_max == {"core": 4}
    assert bf(shape={"core": 3}) is f2
    assert calls == [{"core": 2}, {"core": 4}]
    assert memoize_build(bf) is bf          # idempotent re-wrap

    def plain(n, mode="x"):
        return (n, mode)

    bp = memoize_build(plain)
    assert bp(3) is bp(3)                   # positional args memoize too
    assert bp(3) is not bp(4)
    assert bp(3, mode="y") is not bp(3)


# ---------------------------------------------------------------------------
# Objective: scalarization, domination ranking, fronts
# ---------------------------------------------------------------------------
def test_objective_scalar_and_order():
    obj = Objective({"t": "min", "q": "max"}, weights={"q": 2.0})
    assert obj.scalar({"t": 3.0, "q": 1.0}) == 3.0 - 2.0
    assert obj.scalar({"t": float("nan"), "q": 1.0}) == float("inf")
    assert obj.scalar({"q": 1.0}) == float("inf")          # missing col
    rows = [{"t": 2.0, "q": 1.0},     # dominated by row 2
            {"t": 5.0, "q": 9.0},     # non-dominated (best q)
            {"t": 1.0, "q": 1.0},     # non-dominated (best t)
            {"t": 9.0, "q": 0.5}]     # dominated by everything
    order = obj.order(rows)
    assert set(order[:2]) == {1, 2}   # non-dominated rows promoted first
    assert order[-1] == 3
    # single objective: plain stable sort on the column
    assert Objective("t").order(rows) == [2, 0, 1, 3]


def test_objective_order_ranks_failed_trials_last():
    """A NaN/missing-objective trial is never dominated (NaN compares
    false), so domination count alone would promote it over finished
    but dominated rows — failed trials must rank behind every finished
    one."""
    obj = Objective({"a": "min", "b": "min"})
    rows = [{"a": 2.0, "b": 2.0},                  # dominated by row 1
            {"a": 1.0, "b": 1.0},                  # the winner
            {"a": float("nan"), "b": 3.0},         # failed trial
            {"a": 0.5}]                            # missing objective
    order = obj.order(rows)
    assert order[:2] == [1, 0]
    assert set(order[2:]) == {2, 3}


def test_trial_cycles_nan_virtual_time_falls_back_to_horizon():
    """A NaN virtual_time must not poison the cumulative budget (NaN
    budget would disarm cycle_budget forever)."""
    drv = RandomSearch(AXES_SYN, "f", horizon=50.0, batch=2, rounds=2,
                       seed=0, cycle_budget=150.0)
    pts, us = drv.ask()
    drv.tell([{**p, "f": 1.0, "virtual_time": float("nan")}
              for p in pts])
    assert drv.state.budget == pytest.approx(100.0)   # 2 lanes x horizon
    pts, us = drv.ask()
    drv.tell([{**p, "f": 1.0, "virtual_time": 40.0} for p in pts])
    assert drv.state.budget == pytest.approx(180.0)
    assert drv.done                                   # the cap still arms


@pytest.mark.parametrize("acq", ["ts", "ucb", "qei"])
def test_batch_bo_proposes_distinct_points_on_small_choice_spaces(acq):
    """Duplicate pool candidates tie on every acquisition value — every
    batch (warmup and model rounds alike) must be distinct design
    points, not distinct pool indices — and an exhausted space ends the
    search instead of re-proposing."""
    axes = {"a": [1, 2, 3, 4], "b": [1, 2, 3]}        # 12 combos
    bo = BatchBO(axes, "f", horizon=1.0, batch=5, rounds=5, pool=64,
                 seed=0, acquisition=acq)
    proposed = []
    while True:
        asked = bo.ask()
        if asked is None:
            break
        pts, _ = asked
        keys = [(p["a"], p["b"]) for p in pts]
        assert len(set(keys)) == len(keys), keys      # distinct in-batch
        proposed += keys
        bo.tell([{**p, "f": float(p["a"] + p["b"]), "virtual_time": 1.0}
                 for p in pts])
    # never re-proposed across rounds, and covered the whole space
    assert len(set(proposed)) == len(proposed) == 12


def test_qei_batch_diversity_beats_naive_topk_thompson():
    """qEI's constant liar must spread a batched ask: after one warmup
    round on real memsys-grid objectives, the qEI batch's mean pairwise
    distance (in the surrogate's unit cube) beats the *naive top-k* of
    a single Thompson draw — k best indices of one posterior sample,
    which cluster around that draw's minimum basin."""
    axes = {"conn_latency[-1]": [float(v) for v in range(6, 38, 2)],
            "kind.l1.extra_hit_rate": [0.0, 0.2, 0.4, 0.6, 0.8]}
    grid = SweepSpec.grid(axes)
    bf = memoize_build(lambda: build(n_cores=3, pattern="mixed", n_reqs=6,
                                     donate=True))
    rows = run_sweep(bf, grid, until=400.0)
    table = {(r["conn_latency[-1]"], r["kind.l1.extra_hit_rate"]):
             r["virtual_time"] for r in rows}

    def f(p):
        return table[(p["conn_latency[-1]"], p["kind.l1.extra_hit_rate"])]

    def warmed(acq):
        bo = BatchBO(axes, "virtual_time", horizon=400.0, batch=6,
                     rounds=2, pool=256, seed=0, acquisition=acq)
        pts, us = bo.ask()                      # random warmup round
        bo.tell([{**p, "virtual_time": f(p)} for p in pts])
        return bo

    def spread(bo, pts):
        x = bo._encode(pts)
        d = np.sqrt(((x[:, None] - x[None, :]) ** 2).sum(-1))
        n = len(pts)
        return float(d.sum() / (n * (n - 1)))

    qei = warmed("qei")
    qei_pts, _ = qei.ask()

    # the naive baseline from the *identical* surrogate state: one joint
    # Thompson draw over the same candidate pool, take its k best
    ref = warmed("ts")
    hist = ref.state.history
    seen = {ref._key(t) for t in hist}
    cand = []
    for p in SweepSpec.random(axes, ref.pool, seed=ref._draw_seed()):
        k = ref._key(p)
        if k not in seen:
            seen.add(k)
            cand.append(p)
    x = ref._encode(hist)
    y = np.asarray([float(t["virtual_time"]) for t in hist], np.float64)
    yn = (y - y.mean()) / (y.std() or 1.0)
    mean, cov = ref._posterior(x, yn, ref._encode(cand))
    low = np.linalg.cholesky(cov + 1e-9 * np.eye(len(cand)))
    draw = mean + low @ np.random.default_rng(0).standard_normal(len(cand))
    naive = [dict(cand[i]) for i in np.argsort(draw, kind="stable")[:6]]

    assert len(qei_pts) == len(naive) == 6
    assert spread(qei, qei_pts) > spread(ref, naive)


def test_objective_front_uses_pareto():
    obj = Objective({"t": "min", "q": "max"})
    rows = [{"t": 1.0, "q": 1.0}, {"t": 2.0, "q": 2.0},
            {"t": 3.0, "q": 1.5}]
    assert obj.front(rows) == rows[:2]


def test_multi_objective_halving_promotes_non_dominated(ctx):
    bf, sim, extract, pool, _ = ctx
    obj = Objective({"est_finish": "min", "kind.l1.extra_hit_rate": "min"})
    drv = SuccessiveHalving(pool, obj, max_horizon=MAX_H,
                            min_horizon=60.0, eta=3, seed=0)
    res = run_search(bf, drv, extract=extract)
    assert len(res.front) >= 1
    front = obj.front(res.front)
    assert front == res.front               # front is itself non-dominated
    assert all(t["until"] == MAX_H for t in res.front)


# ---------------------------------------------------------------------------
# BatchBO / RandomSearch on a synthetic objective (no simulator): the
# ask/tell contract is host-side, so convergence is testable directly.
# ---------------------------------------------------------------------------
def _drive(driver, fn):
    while True:
        asked = driver.ask()
        if asked is None:
            return driver
        pts, us = asked
        driver.tell([{**p, "f": fn(p), "virtual_time": u}
                     for p, u in zip(pts, us)])


def _quad(p):
    return (p["x"] - 0.31) ** 2 + (p["y"] - 0.68) ** 2


AXES_SYN = {"x": (0.0, 1.0), "y": (0.0, 1.0)}


def test_batch_bo_converges_and_beats_random():
    bo = _drive(BatchBO(AXES_SYN, "f", horizon=1.0, batch=8, rounds=6,
                        pool=128, seed=3), _quad)
    rs = _drive(RandomSearch(AXES_SYN, "f", horizon=1.0, batch=8, rounds=6,
                             seed=3), _quad)
    assert len(bo.state.history) == len(rs.state.history) == 48
    assert bo.best()["f"] < 0.02            # near the (0.31, 0.68) optimum
    assert bo.best()["f"] < rs.best()["f"]  # the surrogate earns its keep


def test_batch_bo_ucb_and_log_and_choice_axes():
    axes = {"x": (0.1, 10.0, "log"), "k": [1, 2, 4, 8], "y": (0.0, 1.0)}

    def fn(p):
        return (math.log10(p["x"]) - 0.5) ** 2 + (p["k"] - 4) ** 2 / 16.0 \
            + (p["y"] - 0.5) ** 2

    bo = _drive(BatchBO(axes, "f", horizon=1.0, batch=6, rounds=5,
                        pool=96, seed=7, acquisition="ucb"), fn)
    best = bo.best()
    assert best["f"] < 0.15
    assert type(best["k"]) is int           # choice axes stay Python ints


def test_batch_bo_is_reproducible_and_resumable():
    b1 = _drive(BatchBO(AXES_SYN, "f", horizon=1.0, batch=4, rounds=4,
                        pool=64, seed=11), _quad)
    b2 = _drive(BatchBO(AXES_SYN, "f", horizon=1.0, batch=4, rounds=4,
                        pool=64, seed=11), _quad)
    assert b1.state.history == b2.state.history

    # stop after 2 rounds, serialize, resume: identical remaining rounds
    b3 = BatchBO(AXES_SYN, "f", horizon=1.0, batch=4, rounds=4,
                 pool=64, seed=11)
    for _ in range(2):
        pts, us = b3.ask()
        b3.tell([{**p, "f": _quad(p), "virtual_time": u}
                 for p, u in zip(pts, us)])
    state = SearchState.from_json(b3.state.to_json())
    b4 = _drive(BatchBO(AXES_SYN, "f", horizon=1.0, batch=4, rounds=4,
                        pool=64, seed=11, state=state), _quad)
    assert b4.state.history == b1.state.history


def test_batch_bo_never_reproposes_evaluated_points():
    seen = []

    def fn(p):
        seen.append((p["x"], p["y"]))
        return _quad(p)

    _drive(BatchBO(AXES_SYN, "f", horizon=1.0, batch=8, rounds=5,
                   pool=64, seed=5), fn)
    assert len(seen) == len(set(seen))


def test_random_search_determinism_and_budget_cap():
    r1 = _drive(RandomSearch(AXES_SYN, "f", horizon=100.0, batch=8,
                             rounds=4, seed=2), _quad)
    r2 = _drive(RandomSearch(AXES_SYN, "f", horizon=100.0, batch=8,
                             rounds=4, seed=2), _quad)
    assert r1.state.history == r2.state.history
    assert r1.state.budget == pytest.approx(100.0 * 32)
    capped = _drive(RandomSearch(AXES_SYN, "f", horizon=100.0, batch=8,
                                 rounds=4, seed=2, cycle_budget=1500.0),
                    _quad)
    assert capped.state.round == 2          # 1600 >= 1500 after round 2
    assert capped.state.history == r1.state.history[:16]


def test_search_state_json_roundtrip_preserves_everything():
    s = SearchState(round=3, budget=123.5,
                    history=[{"a": 1.0, "until": 10.0, "round": 0}],
                    driver={"brackets": [{"rung": 1, "alive": [{"a": 1}]}]},
                    rng=np.random.default_rng(9).bit_generator.state)
    back = SearchState.from_json(s.to_json())
    assert back == s
    assert json.loads(s.to_json())["budget"] == 123.5
    # the restored rng state drives an identical stream
    g = np.random.default_rng(0)
    g.bit_generator.state = back.rng
    h = np.random.default_rng(9)
    assert g.integers(0, 1 << 30) == h.integers(0, 1 << 30)


def test_tell_without_ask_raises():
    drv = RandomSearch(AXES_SYN, "f", horizon=1.0, batch=2, rounds=1)
    with pytest.raises(AssertionError, match="pending ask"):
        drv.tell([])
