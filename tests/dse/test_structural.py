"""Topology families (DSE.md): a padded build at the family maximum plus
traced activity masks must be **bit-identical on active rows** to an
unpadded build of each sub-shape — the invariant that makes structural
(shape-axis) sweeps trustworthy.

Active-row observables: virtual time, scalar Stats, per-kind component
state rows, per-kind port *counts*, and the per-kind ``next_tick`` /
``busy`` slices.  Raw ring-buffer words are excluded by design — messages
carry global port ids, which are build-relative (the padded build numbers
ports differently), so buffer bytes are representation, not observation.

Shapes are exercised both through single masked runs and through the real
mechanism — one vmapped batch whose lanes are different shapes of one
compiled family — and ``run_sweep`` must build/compile once per family,
not once per shape.
"""
import jax
import numpy as np
import pytest

from repro.dse import (BatchRunner, SweepSpec, run_sweep, stack_params,
                       stack_state_list)
from repro.sims import onira
from repro.sims.memsys import build, build_family, finish_stats

PATTERNS = ["compute", "stream", "pointer", "idle_half", "mixed"]
STAT_FIELDS = ("epochs", "ticks", "progress_ticks", "delivered")
N_MAX = 4


def assert_active_rows_identical(fam_sim, fam_out, ref_sim, ref_out,
                                 counts):
    assert float(fam_out.time) == float(ref_out.time)
    for f in STAT_FIELDS:
        assert int(getattr(fam_out.stats, f)) == \
            int(getattr(ref_out.stats, f)), f
    for k in ref_sim.kinds:
        n = counts.get(k.name, k.n_instances)
        fb, rb = fam_sim.comp_id(k.name, 0), ref_sim.comp_id(k.name, 0)
        np.testing.assert_array_equal(
            np.asarray(fam_out.next_tick)[fb:fb + n],
            np.asarray(ref_out.next_tick)[rb:rb + n], err_msg=k.name)
        np.testing.assert_array_equal(
            np.asarray(fam_out.stats.busy)[fb:fb + n],
            np.asarray(ref_out.stats.busy)[rb:rb + n], err_msg=k.name)
        for leaf in ref_out.comp_state[k.name]:
            np.testing.assert_array_equal(
                np.asarray(fam_out.comp_state[k.name][leaf])[:n],
                np.asarray(ref_out.comp_state[k.name][leaf])[:n],
                err_msg=f"{k.name}.{leaf}")
        np_act = n * k.n_ports
        for seg_f, seg_r in ((fam_out.in_cnt, ref_out.in_cnt),
                             (fam_out.out_cnt, ref_out.out_cnt)):
            np.testing.assert_array_equal(
                np.asarray(seg_f[k.name])[:np_act],
                np.asarray(seg_r[k.name])[:np_act], err_msg=k.name)


@pytest.mark.parametrize("pattern", PATTERNS)
def test_memsys_family_lanes_match_unpadded_builds(pattern):
    """One vmapped family batch, one lane per shape, vs per-shape builds."""
    shapes = [2, 4]
    fam = build_family(n_cores=N_MAX, pattern=pattern, n_reqs=10,
                       donate=False)
    pb = stack_params([fam.params_for({"core": s}) for s in shapes])
    sb = stack_state_list([fam.state_for({"core": s}) for s in shapes])
    out = BatchRunner(fam.sim).run_batch(sb, pb, 20000.0)
    for i, s in enumerate(shapes):
        lane_out = jax.tree.map(lambda x: x[i], out)
        ref_sim, ref_st = build(n_cores=s, pattern=pattern, n_reqs=10,
                                donate=False)
        ref = ref_sim.run(ref_st, until=20000.0)
        assert_active_rows_identical(fam.sim, lane_out, ref_sim, ref,
                                     {"core": s, "l1": s, "dram": 1})
        stats = finish_stats(ref_sim, ref)
        if pattern != "idle_half":
            assert stats["reads_done"] > 0      # not vacuous
        assert stats["remaining"] == 0


def test_memsys_family_matches_unpadded_mid_flight():
    """Equality must hold mid-run too (non-empty queues, finite wakes),
    not just at the drained fixpoint."""
    fam = build_family(n_cores=N_MAX, pattern="mixed", n_reqs=16,
                       donate=False)
    out = fam.sim.run(fam.state_for({"core": 2}), until=150.0,
                      params=fam.params_for({"core": 2}))
    ref_sim, ref_st = build(n_cores=2, pattern="mixed", n_reqs=16,
                            donate=False)
    ref = ref_sim.run(ref_st, until=150.0)
    assert finish_stats(ref_sim, ref)["remaining"] > 0    # genuinely mid-run
    assert_active_rows_identical(fam.sim, out, ref_sim, ref,
                                 {"core": 2, "l1": 2, "dram": 1})


def test_masked_rows_stay_inert_and_pinned():
    fam = build_family(n_cores=N_MAX, pattern="stream", n_reqs=8,
                       donate=False)
    st = fam.state_for({"core": 2})
    out = fam.sim.run(st, until=20000.0, params=fam.params_for({"core": 2}))
    cs = out.comp_state
    # masked cores never issued, masked L1s never served
    assert np.asarray(cs["core"]["remaining"])[2:].tolist() == [0, 0]
    assert np.asarray(cs["l1"]["hits"])[2:].tolist() == [0, 0]
    assert np.asarray(cs["l1"]["misses"])[2:].tolist() == [0, 0]
    assert not np.asarray(out.stats.busy)[2:N_MAX].any()
    # pinned out of the next-event min
    assert np.isinf(np.asarray(out.next_tick)[2:N_MAX]).all()


def test_onira_family_cpi_matches_unpadded():
    names = ["ALU", "RAW_HZD", "BR_LOOP", "IND_LD"]
    progs = [onira.MICROBENCHES[n]() for n in names]
    fam = onira.build_onira_family(progs, mem_latency=5.0)
    for s in (1, 2, 4):
        out = fam.sim.run(fam.state_for({"cpu": s}), until=20000.0,
                          params=fam.params_for({"cpu": s}))
        ref_sim, ref_st = onira.build_onira(progs[:s], mem_latency=5.0)
        ref = ref_sim.run(ref_st, until=20000.0)
        assert_active_rows_identical(fam.sim, out, ref_sim, ref,
                                     {"cpu": s, "mem": s})
        cs = out.comp_state["cpu"]
        assert np.asarray(cs["done"])[:s].all()
        for i in range(s):      # CPI still tracks the analytic model
            cpi = float(cs["halt_time"][i]) / max(int(cs["retired"][i]), 1)
            ref_cpi = onira.analytic_cpi(names[i])
            assert abs(cpi - ref_cpi) / ref_cpi < 0.35, (names[i], cpi)


# ---------------------------------------------------------------------------
def test_run_sweep_shape_axes_build_once_per_family():
    """A shape grid is ONE family build (and one compiled batch), not one
    compile group per shape; static axes still split compile groups."""
    calls = []

    def build_fn(shape, super_epoch=None):
        calls.append((dict(shape), super_epoch))
        return build_family(shape=shape, n_cores=N_MAX, pattern="mixed",
                            n_reqs=8, super_epoch=super_epoch)

    spec = SweepSpec.grid({"shape.core": [1, 2, 4],
                           "kind.l1.extra_hit_rate": [0.0, 0.5],
                           "static.super_epoch": [1, 4]})
    rows = run_sweep(build_fn, spec, until=50000.0,
                     extract=lambda sim, s: finish_stats(sim, s))
    # one family build per static group, each at the family max shape
    assert calls == [({"core": 4}, 1), ({"core": 4}, 4)]
    assert [r["shape.core"] for r in rows] == [1, 1, 1, 1, 2, 2, 2, 2,
                                               4, 4, 4, 4]
    assert all(r["remaining"] == 0 for r in rows)
    # more active cores -> more DRAM reads served at hit_rate 0
    served = {r["shape.core"]: r["reads_done"] for r in rows
              if r["kind.l1.extra_hit_rate"] == 0.0
              and r["static.super_epoch"] == 1}
    assert served[1] < served[2] < served[4]
    # super_epoch is observation-invariant across the family too
    for i in range(0, len(rows), 2):
        assert rows[i]["virtual_time"] == rows[i + 1]["virtual_time"]


def test_family_shape_validation():
    fam = build_family(n_cores=N_MAX, pattern="mixed", n_reqs=4)
    with pytest.raises(ValueError, match="outside this family's range"):
        fam.state_for({"core": N_MAX + 1})
    with pytest.raises(ValueError, match="unknown shape axes"):
        fam.params_for({"nope": 2})
    # missing axes default to the family maximum
    assert fam.full_shape({}) == {"core": N_MAX}


def test_shape_axis_against_plain_simulation_is_rejected():
    spec = SweepSpec.grid({"shape.core": [1, 2]})
    sim, _ = build(n_cores=2, pattern="mixed", n_reqs=4, donate=False)
    with pytest.raises(ValueError, match="topology family"):
        spec.validate(sim)
