"""Warm-state resume (DSE.md "Warm-state promotions"): the invariants
that make state-resumed rung promotion trustworthy:

* **bit-identity** — a lane resumed from its frozen rung-k state and run
  to horizon H produces the same row *and the same final state* as a
  cold run to H, on every memsys pattern and on masked topology-family
  lanes (the engine's epoch sequence is state-determined; ``until`` is
  an absolute traced operand);
* the resumed path retraces nothing (same batched executables);
* a warm `SuccessiveHalving` search produces the identical trajectory
  as a replay-from-zero (``warm=False``) search while charging only the
  horizon increments to the budget;
* a search interrupted mid-ladder and resumed through `repro.ckpt` rung
  checkpoints (`save_search` / `load_search`) is bit-identical to the
  uninterrupted one — rows, promotions and cumulative budget;
* Hyperband per-bracket budget caps stop an exhausted bracket without
  touching its siblings.
"""
import os

import jax
import numpy as np
import pytest

from repro.dse import (ResumeHandle, SuccessiveHalving, SweepSpec,
                       load_search, memoize_build, run_search, run_sweep,
                       runner_for, save_search)
from repro.sims.memsys import build, build_family

PATTERNS = ["compute", "stream", "pointer", "idle_half", "mixed"]


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# run_sweep-level bit-identity: resume == cold, rows and final states
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pattern", PATTERNS)
def test_resumed_rows_bit_identical_all_patterns(pattern):
    bf = memoize_build(
        lambda pattern=pattern: build(n_cores=3, pattern=pattern,
                                      n_reqs=6, donate=True))
    pts = [{"conn_latency[-1]": float(v)} for v in (10, 25, 40)]
    spec = SweepSpec.explicit(pts)
    u1, u2 = 250.0, 1000.0
    _, mid = run_sweep(bf, spec, until=u1, return_states=True)
    handles = [mid.handle(i, u1) for i in range(len(pts))]
    warm_rows, ws = run_sweep(bf, spec, until=u2, resume=handles,
                              return_states=True)
    cold_rows, cs = run_sweep(bf, spec, until=u2, return_states=True)
    assert warm_rows == cold_rows
    for i in range(len(pts)):
        _assert_tree_equal(ws.state(i), cs.state(i))


def test_family_masked_lane_warm_resume_bit_identical():
    """shape.* lanes resume exactly like plain lanes: the frozen state
    carries the mask pinning (inactive next_tick stays +inf), so a
    resumed masked lane equals a cold masked run at the longer
    horizon."""
    bf = memoize_build(
        lambda shape=None: build_family(shape=shape, pattern="mixed",
                                        n_reqs=6, donate=True))
    pts = [{"shape.core": c, "conn_latency[-1]": u}
           for c, u in ((1, 10.0), (2, 25.0), (3, 40.0), (2, 40.0))]
    spec = SweepSpec.explicit(pts)
    u1, u2 = 250.0, 1000.0
    _, mid = run_sweep(bf, spec, until=u1, return_states=True)
    handles = [mid.handle(i, u1) for i in range(len(pts))]
    warm_rows, ws = run_sweep(bf, spec, until=u2, resume=handles,
                              return_states=True)
    cold_rows, cs = run_sweep(bf, spec, until=u2, return_states=True)
    assert warm_rows == cold_rows
    for i in range(len(pts)):
        _assert_tree_equal(ws.state(i), cs.state(i))


def test_partial_resume_mixes_warm_and_cold_lanes():
    """resume= may hand only some lanes a handle — handled lanes
    continue, the rest start cold, in one stacked batch."""
    bf = memoize_build(lambda: build(n_cores=3, pattern="mixed", n_reqs=6,
                                     donate=True))
    pts = [{"conn_latency[-1]": float(v)} for v in (10, 25, 40)]
    spec = SweepSpec.explicit(pts)
    u1, u2 = 250.0, 1000.0
    _, mid = run_sweep(bf, spec, until=u1, return_states=True)
    handles = [mid.handle(0, u1), None, mid.handle(2, u1)]
    warm_rows = run_sweep(bf, spec, until=u2, resume=handles)
    cold_rows = run_sweep(bf, spec, until=u2)
    assert warm_rows == cold_rows


def test_resume_handle_length_mismatch_raises():
    bf = memoize_build(lambda: build(n_cores=2, pattern="mixed", n_reqs=4,
                                     donate=True))
    spec = SweepSpec.explicit([{"conn_latency[-1]": 10.0}] * 2)
    with pytest.raises(ValueError, match="one handle"):
        run_sweep(bf, spec, until=100.0, resume=[None])


def test_resumed_path_retraces_nothing():
    """Resuming re-enters the same compiled executables: ``until`` and
    ``max_epochs`` are traced operands and per-lane initial states stack
    outside the jit, so the warm path costs zero retraces."""
    bf = memoize_build(lambda: build(n_cores=3, pattern="mixed", n_reqs=6,
                                     donate=True))
    sim, _ = bf()
    pts = [{"conn_latency[-1]": float(v)} for v in (10, 20, 30, 40)]
    spec = SweepSpec.explicit(pts)
    _, mid = run_sweep(bf, spec, until=250.0, return_states=True)
    runner = runner_for(sim)
    t0 = runner.trace_count
    handles = [mid.handle(i, 250.0) for i in range(len(pts))]
    run_sweep(bf, spec, until=1000.0, resume=handles)
    assert runner.trace_count == t0, (
        f"{runner.trace_count - t0} retraces on the resumed path")


# ---------------------------------------------------------------------------
# search-level: warm == cold trajectories, incremental budget, ckpt resume
# ---------------------------------------------------------------------------
POOL = [{"conn_latency[-1]": float(v)} for v in range(6, 42, 4)]
LADDER = dict(max_horizon=2000.0, min_horizon=2000.0 / 9, eta=3, seed=0)


def _bf():
    return memoize_build(lambda: build(n_cores=3, pattern="mixed",
                                       n_reqs=8, donate=True))


def test_warm_search_matches_cold_rows_for_less_budget():
    bf = _bf()
    cold = run_search(bf, SuccessiveHalving(POOL, "virtual_time",
                                            warm=False, **LADDER))
    warm = run_search(bf, SuccessiveHalving(POOL, "virtual_time",
                                            warm=True, **LADDER))
    strip = lambda rows: [{k: v for k, v in r.items() if k != "cycles"}
                          for r in rows]
    assert strip(warm.rows) == strip(cold.rows)   # identical trajectory
    assert warm.best == {**cold.best, "cycles": warm.best["cycles"]}
    assert warm.budget < cold.budget              # ...for increments only
    # cold charges each trial its full virtual time; warm's total is the
    # cold total minus every promoted prefix (telescoping sums)
    assert cold.budget == pytest.approx(
        sum(t["virtual_time"] for t in cold.rows))
    assert warm.budget == pytest.approx(
        sum(t["cycles"] for t in warm.rows))


def test_ckpt_resume_mid_ladder_bit_identical(tmp_path):
    """Interrupt a warm search at every round boundary, persist it with
    save_search (rung states through repro.ckpt), restore with
    load_search + adopt_handles: rows, best and *budget* all match the
    uninterrupted search exactly — completed rungs are never re-paid."""
    bf = _bf()
    _, st_t = bf()
    snaps = []

    def cb(drv):
        snaps.append(save_search(str(tmp_path / f"r{drv.state.round}"),
                                 drv))

    full = run_search(bf, SuccessiveHalving(POOL, "virtual_time",
                                            **LADDER), callback=cb)
    assert len(snaps) == full.rounds
    for k in range(full.rounds - 1):      # resume from every boundary
        state, handles = load_search(str(tmp_path / f"r{k + 1}"), st_t)
        drv = SuccessiveHalving(POOL, "virtual_time", **LADDER,
                                state=state)
        drv.adopt_handles(handles)
        assert all(isinstance(h, ResumeHandle) for h in handles.values())
        resumed = run_search(bf, drv)
        assert resumed.rows == full.rows
        assert resumed.best == full.best
        assert resumed.budget == full.budget
        assert resumed.rounds == full.rounds - (k + 1)
    # the rung checkpoints themselves are small, real files
    step0 = snaps[0]
    assert os.path.isfile(os.path.join(step0, "arrays.npz"))
    assert os.path.isfile(os.path.join(step0, "manifest.json"))


def test_warm_search_repeat_retraces_nothing():
    bf = _bf()
    sim, _ = bf()
    run_search(bf, SuccessiveHalving(POOL, "virtual_time", **LADDER))
    runner = runner_for(sim)
    t0 = runner.trace_count
    res = run_search(bf, SuccessiveHalving(POOL, "virtual_time", **LADDER))
    assert runner.trace_count == t0, (
        f"{runner.trace_count - t0} retraces in a repeat warm search")
    assert res.best is not None


# ---------------------------------------------------------------------------
# Hyperband per-bracket budget caps
# ---------------------------------------------------------------------------
def test_bracket_budget_caps_stop_only_the_exhausted_bracket():
    bf = _bf()
    free = run_search(bf, SuccessiveHalving(POOL, "virtual_time",
                                            brackets=2, **LADDER))
    spent = [br["spent"]
             for br in free.state.driver["brackets"]]
    assert all(s > 0 for s in spent)      # every bracket tracks its spend
    assert sum(spent) == pytest.approx(free.budget)
    # cap bracket 0 below its free-running spend; bracket 1 runs free
    caps = [spent[0] * 0.5, float("inf")]
    capped = run_search(bf, SuccessiveHalving(
        POOL, "virtual_time", brackets=2, bracket_budgets=caps, **LADDER))
    brs = capped.state.driver["brackets"]
    assert brs[0]["spent"] < spent[0]     # bracket 0 stopped early
    assert brs[0]["alive"]                # ...mid-ladder, not drained
    assert brs[1]["spent"] == pytest.approx(spent[1])   # sibling untouched
    assert capped.best is not None


def test_bracket_budgets_equal_split_and_validation():
    drv = SuccessiveHalving(POOL, "virtual_time", brackets=2,
                            cycle_budget=1000.0, bracket_budgets="equal",
                            **{k: v for k, v in LADDER.items()})
    caps = [br["budget"] for br in drv.state.driver["brackets"]]
    assert caps == [500.0, 500.0]
    with pytest.raises(AssertionError, match="bracket budgets"):
        SuccessiveHalving(POOL, "virtual_time", brackets=2,
                          bracket_budgets=[1.0], **LADDER)
    with pytest.raises(AssertionError, match="cycle_budget"):
        SuccessiveHalving(POOL, "virtual_time", brackets=2,
                          bracket_budgets="equal", **LADDER)
