"""Round pipelining (ENGINE_PERF.md "Round pipelining").

The contract: pipelining is an *execution strategy*, never a semantic —
``pipeline=2`` (the default) must produce bit-identical results to the
strictly-alternating loop (``pipeline=False``) and to a monolithic
full-batch run, on every memsys pattern, on masked family lanes and on
the 2-device sharded path; and it must not cost any recompiles (the
in-flight rounds reuse the same per-rung executables).
"""
import numpy as np
import pytest

from repro.dse import (BatchRunner, ChunkSchedule, build_param_batch,
                       make_ladder, run_sweep, stack_params,
                       stack_state_list, stack_states)
from repro.obs.bus import capture
from repro.sims.memsys import build, build_family

from test_sharded import _run_two_device

PATTERNS = ["compute", "stream", "pointer", "idle_half", "mixed"]


def _assert_tree_equal(a, b):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _sched(b, top=2, quantum=24):
    return ChunkSchedule(make_ladder(b, top=top), quantum=quantum)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pattern", PATTERNS)
def test_pipelined_bit_identical_all_patterns(pattern):
    """pipeline=2 == pipeline=False == full batch, on every pattern,
    at mixed per-lane horizons through real compaction."""
    sim, st = build(n_cores=3, pattern=pattern, n_reqs=6, donate=True)
    runner = BatchRunner(sim)
    pts = [{"conn_latency[-1]": float(v)} for v in (10, 25, 40, 15, 30, 20)]
    pb = build_param_batch(sim, pts)
    u = np.asarray([150.0, 1200.0, 600.0, 300.0, 900.0, 75.0], np.float32)
    full = runner.run_batch(stack_states(st, 6), pb, u)
    seq = runner.run_rounds(st, pb, u, schedule=_sched(6), pipeline=False)
    assert runner.last_rounds["pipeline"] == 1
    piped = runner.run_rounds(st, pb, u, schedule=_sched(6))
    assert runner.last_rounds["pipeline"] == 2
    _assert_tree_equal(full, seq)
    _assert_tree_equal(seq, piped)


def test_pipelined_family_masked_bit_identical():
    """Masked family lanes (different sub-shapes) ride pipelined rounds
    bit-identically."""
    fam = build_family(n_cores=4, pattern="mixed", n_reqs=8, donate=True)
    shapes = [{"core": c} for c in (1, 2, 3, 4, 2, 3)]
    untils = np.asarray([300.0, 900.0, 150.0, 1200.0, 600.0, 75.0],
                        np.float32)
    pb = stack_params([fam.params_for(s) for s in shapes])
    states = [fam.state_for(s) for s in shapes]
    runner = BatchRunner(fam.sim)
    seq = runner.run_rounds(states, pb, untils, schedule=_sched(6),
                            pipeline=False)
    piped = runner.run_rounds(states, pb, untils, schedule=_sched(6))
    assert runner.last_rounds["rounds"] > 2
    _assert_tree_equal(seq, piped)


def test_pipeline_actually_overlaps_and_reports_occupancy():
    """With several rounds in a drain, dispatch runs ahead of resolve:
    round.end events see a non-empty in-flight queue, and both the
    per-round and run-level occupancy stats are populated."""
    sim, st = build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)
    runner = BatchRunner(sim)
    pts = [{"conn_latency[-1]": float(v)} for v in (10, 25, 40, 15, 30, 20)]
    pb = build_param_batch(sim, pts)
    u = np.asarray([150.0, 1200.0, 600.0, 300.0, 900.0, 75.0], np.float32)
    with capture() as sink:
        runner.run_rounds(st, pb, u, schedule=_sched(6, quantum=16))
    ends = [e for e in sink.events if e["kind"] == "round.end"]
    assert len(ends) > 2
    assert any(e["inflight"] > 0 for e in ends)
    for e in ends:
        assert 0.0 <= e["overlap_frac"] <= 1.0
        assert e["host_s"] >= 0.0 and e["wait_s"] >= 0.0
    starts = [e for e in sink.events if e["kind"] == "rounds.start"]
    assert starts and starts[0]["pipeline"] == 2
    lr = runner.last_rounds
    assert lr["pipeline"] == 2
    assert 0.0 <= lr["overlap_frac"] <= 1.0


def test_pipelined_rounds_no_recompiles_after_warmup():
    """After the ladder warms up, pipelined re-runs retrace nothing —
    in-flight depth never creates new executables."""
    sim, st = build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)
    runner = BatchRunner(sim)
    pts = [{"conn_latency[-1]": float(v)} for v in (10, 25, 40, 15, 30, 20)]
    pb = build_param_batch(sim, pts)
    u = np.asarray([150.0, 1200.0, 600.0, 300.0, 900.0, 75.0], np.float32)
    runner.run_rounds(st, pb, u, schedule=_sched(6))       # warmup
    warm = runner.trace_count
    for depth in (2, 3, False):
        runner.run_rounds(st, pb, u, schedule=_sched(6), pipeline=depth)
    # sequential and pipelined share the same per-rung executables
    assert runner.trace_count == warm


def test_run_sweep_pipeline_flag_bit_identical():
    """run_sweep(pipeline=...) forwards to the round loop; rows match
    exactly either way."""
    def b():
        return build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)

    from repro.dse import SweepSpec
    spec = SweepSpec.explicit(
        [{"conn_latency[-1]": float(v)} for v in (10, 25, 40, 15)])
    u = [150.0, 1200.0, 600.0, 300.0]
    seq = run_sweep(b, spec, u, chunk=2, pipeline=False)
    piped = run_sweep(b, spec, u, chunk=2)
    assert seq == piped


def test_pipelined_sharded_two_device_bit_identical():
    """The 2-device shard_map path composes with pipelining: rows stay
    bit-identical to the sequential sharded loop and to the 1-device
    pipelined loop."""
    _run_two_device("""
        import jax, numpy as np
        from repro.dse import (BatchRunner, ChunkSchedule,
                               build_param_batch, make_ladder,
                               stack_states)
        from repro.sims.memsys import build

        assert jax.local_device_count() == 2
        sim, st = build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)
        runner = BatchRunner(sim)
        pts = [{"conn_latency[-1]": float(v)}
               for v in (10, 25, 40, 15, 30, 20)]
        pb = build_param_batch(sim, pts)
        u = np.asarray([150.0, 1200.0, 600.0, 300.0, 900.0, 75.0],
                       np.float32)
        sched = lambda: ChunkSchedule(make_ladder(6, top=4), quantum=24)
        seq1 = runner.run_rounds(st, pb, u, schedule=sched(),
                                 pipeline=False)
        seq2 = runner.run_rounds(st, pb, u, schedule=sched(),
                                 shard=2, pipeline=False)
        piped = runner.run_rounds(st, pb, u, schedule=sched(), shard=2)
        for a, b in ((seq1, seq2), (seq2, piped)):
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_array_equal(np.asarray(x),
                                              np.asarray(y))
        print("OK")
    """)
