"""Straggler semantics of round-based execution (DSE.md "Rounds and the
chunk ladder").

The invariants that make the straggler-free path trustworthy:

* per-lane horizons — a batched lane at ``until=u_i`` is bit-identical
  to an unbatched run at ``u_i`` (vmap freezes each lane with selects);
* rounds + compaction + refill are an *execution strategy*: the result
  is bit-identical to one full-batch ``run_batch`` at the same per-lane
  horizons, for plain batches and masked topology-family batches alike;
* lane order is irrelevant (permutation invariance);
* zero-horizon lanes (the chunk-padding trick) freeze on entry;
* after ladder warmup, further rounds and repeat sweeps never recompile
  (``trace_count`` counts actual retraces).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dse import (BatchRunner, ChunkSchedule, SweepSpec, apply_point,
                       build_param_batch, lane, make_ladder, run_sweep,
                       stack_params, stack_state_list, stack_states)
from repro.sims.memsys import build, build_family

B = 6
POINTS = [{"conn_latency[-1]": float(v)} for v in (10, 15, 20, 25, 30, 35)]
# mixed per-lane horizons with an ~8x straggler spread (and one lane that
# drains long before its horizon)
UNTILS = np.asarray([200.0, 400.0, 800.0, 1600.0, 300.0, 50.0], np.float32)


@pytest.fixture(scope="module")
def ctx():
    sim, st = build(n_cores=4, pattern="mixed", n_reqs=8, donate=True)
    runner = BatchRunner(sim)
    pb = build_param_batch(sim, POINTS)
    return sim, st, runner, pb


def _small_rounds():
    """A schedule that forces several rounds and real compaction."""
    return ChunkSchedule(make_ladder(B, top=3), quantum=32)


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
def test_per_lane_horizons_match_individual_runs():
    """Lane i of a mixed-horizon batch == an unbatched run at until_i."""
    sim, st = build(n_cores=4, pattern="mixed", n_reqs=8, donate=False)
    pb = build_param_batch(sim, POINTS)
    out = BatchRunner(sim).run_batch(stack_states(st, B), pb, UNTILS)
    base = sim.default_params()
    for i in range(B):
        ref = sim._run_jit(sim.copy_state(st), float(UNTILS[i]), 2_000_000,
                           params=apply_point(base, POINTS[i]))
        _assert_tree_equal(lane(out, i), ref)


def test_rounds_bit_identical_to_full_batch_mixed_horizons(ctx):
    sim, st, runner, pb = ctx
    full = runner.run_batch(stack_states(st, B), pb, UNTILS)
    rounds = runner.run_rounds(st, pb, UNTILS, schedule=_small_rounds())
    assert runner.last_rounds["rounds"] > 2   # compaction actually ran
    _assert_tree_equal(full, rounds)


def test_rounds_lane_permutation_invariance(ctx):
    sim, st, runner, pb = ctx
    base = runner.run_rounds(st, pb, UNTILS, schedule=_small_rounds())
    perm = np.asarray([3, 1, 5, 0, 4, 2])
    pb_p = jax.tree.map(lambda x: x[jnp.asarray(perm)], pb)
    out_p = runner.run_rounds(st, pb_p, UNTILS[perm],
                              schedule=_small_rounds())
    for j, i in enumerate(perm):
        _assert_tree_equal(lane(out_p, j), lane(base, i))


def test_no_recompiles_across_rounds_and_repeat_runs(ctx):
    sim, st, runner, pb = ctx
    runner.run_rounds(st, pb, UNTILS, schedule=_small_rounds())  # warmup
    t0 = runner.trace_count
    out = runner.run_rounds(st, pb, UNTILS, schedule=_small_rounds())
    assert runner.last_rounds["rounds"] > 2
    assert runner.trace_count == t0, (
        f"{runner.trace_count - t0} retraces after ladder warmup")
    assert float(lane(out, 3).time) > 0.0


def test_zero_horizon_lanes_freeze_on_entry(ctx):
    """The chunk-padding contract: until=0 + max_epochs=0 lanes come back
    bit-identical to their initial state (zero epochs executed)."""
    sim, st, runner, pb = ctx
    u = UNTILS.copy()
    m = np.full(B, 2_000_000, np.int32)
    u[2] = 0.0
    m[2] = 0
    sb = stack_states(st, B)
    keep = sim.copy_state(sb)
    out = runner.run_batch(sb, pb, u, m)
    frozen = lane(out, 2)
    assert int(frozen.stats.epochs) == 0
    assert float(frozen.time) == 0.0
    _assert_tree_equal(frozen, lane(keep, 2))
    # live lanes were unaffected by the frozen sibling
    assert float(lane(out, 3).time) > 0.0


def test_run_chunked_per_lane_until_and_padded_tail(ctx):
    """Chunked execution (padded tail included) must equal the one-shot
    batch at the same per-lane horizons; padding rides the zero-horizon
    path instead of re-simulating the tail point."""
    sim, st, runner, pb = ctx
    whole = runner.run_batch(stack_states(st, B), pb, UNTILS)
    split = runner.run_chunked(st, pb, UNTILS, chunk=4)   # 4 + 2(+2 pad)
    _assert_tree_equal(whole, split)


# ---------------------------------------------------------------------------
PATTERNS = ["compute", "stream", "pointer", "idle_half", "mixed"]


@pytest.mark.parametrize("pattern", PATTERNS)
def test_rounds_bit_identical_all_patterns(pattern):
    """The pinned-workload sweep: rounds == full batch on every memsys
    pattern, at mixed per-lane horizons, through real compaction."""
    sim, st = build(n_cores=3, pattern=pattern, n_reqs=6, donate=True)
    runner = BatchRunner(sim)
    pts = [{"conn_latency[-1]": float(v)} for v in (10, 25, 40, 15)]
    pb = build_param_batch(sim, pts)
    u = np.asarray([150.0, 1200.0, 600.0, 300.0], np.float32)
    full = runner.run_batch(stack_states(st, 4), pb, u)
    rounds = runner.run_rounds(
        st, pb, u, schedule=ChunkSchedule(make_ladder(4, top=2),
                                          quantum=24))
    _assert_tree_equal(full, rounds)


def test_family_masked_rounds_bit_identical_mixed_horizons():
    """Masked topology-family lanes (different sub-shapes) compose with
    per-lane horizons: rounds == full batch, bit for bit."""
    fam = build_family(n_cores=4, pattern="mixed", n_reqs=8, donate=True)
    shapes = [{"core": c} for c in (1, 2, 3, 4, 2, 3)]
    untils = np.asarray([300.0, 900.0, 150.0, 1200.0, 600.0, 75.0],
                        np.float32)
    pb = stack_params([fam.params_for(s) for s in shapes])
    states = [fam.state_for(s) for s in shapes]
    runner = BatchRunner(fam.sim)
    full = runner.run_batch(stack_state_list(states), pb, untils)
    rounds = runner.run_rounds(states, pb, untils,
                               schedule=ChunkSchedule(make_ladder(6, top=2),
                                                      quantum=24))
    assert runner.last_rounds["rounds"] > 2
    _assert_tree_equal(full, rounds)


def test_run_sweep_per_point_horizons():
    """run_sweep accepts a per-point ``until`` sequence and feeds each
    lane its own horizon through the round loop."""
    spec_points = [{"conn_latency[-1]": 10.0}, {"conn_latency[-1]": 10.0},
                   {"conn_latency[-1]": 30.0}]
    spec = SweepSpec.explicit(spec_points)
    untils = [150.0, 600.0, 600.0]
    rows = run_sweep(lambda: build(n_cores=3, pattern="mixed", n_reqs=6,
                                   donate=True),
                     spec, until=untils)
    # same config, shorter horizon => no-later virtual time, fewer epochs
    assert rows[0]["virtual_time"] <= rows[1]["virtual_time"]
    assert rows[0]["epochs"] < rows[1]["epochs"]
    assert rows[0]["virtual_time"] <= 150.0 + 1.0


def test_make_ladder_clamps_degenerate_tops():
    from repro.dse import make_ladder
    assert make_ladder(16, top=0) == (1,)       # must not hang
    assert make_ladder(16, top=-3) == (1,)
    assert make_ladder(5) == (5,)
    assert make_ladder(256) == (256, 128, 64, 32, 16, 8)
    assert make_ladder(16, top=8, min_rung=4) == (8, 4)


def test_runner_for_is_shared_per_sim():
    from repro.dse import runner_for
    sim, _ = build(n_cores=2, pattern="mixed", n_reqs=4, donate=True)
    assert runner_for(sim) is runner_for(sim)   # repeat sweeps reuse it
    sim2, _ = build(n_cores=2, pattern="mixed", n_reqs=4, donate=True)
    assert runner_for(sim2) is not runner_for(sim)


def test_consumed_template_raises_clear_error_in_rounds():
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4, donate=True)
    sim.run(st, 500.0)                          # consumes st
    runner = BatchRunner(sim)
    pb = build_param_batch(sim, [{}, {}])
    with pytest.raises(RuntimeError, match="copy_state"):
        runner.run_rounds(st, pb, 500.0)


def test_autotuned_rounds_match_full_batch():
    """The autotune probe rounds are real sweep work: results with
    autotune on are still bit-identical, the winning rung is cached for
    later ``schedule=None`` runs, and a repeat sweep (which re-probes,
    since the explicit schedule asks for it) retraces nothing."""
    sim, st = build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)
    runner = BatchRunner(sim)
    B2 = 16
    pts = [{"conn_latency[-1]": 10.0 + 2.0 * i} for i in range(B2)]
    pb = build_param_batch(sim, pts)
    u = np.asarray([100.0 * (1 + (i % 8)) for i in range(B2)], np.float32)
    full = runner.run_batch(stack_states(st, B2), pb, u)
    sched = ChunkSchedule(make_ladder(B2, top=8, min_rung=4), quantum=16,
                          autotune=True, probe_rungs=2)
    tuned = runner.run_rounds(st, pb, u, schedule=sched)
    _assert_tree_equal(full, tuned)
    assert runner._tuned_top  # winner cached for later schedule=None runs
    t0 = runner.trace_count
    again = runner.run_rounds(st, pb, u, schedule=sched)
    assert runner.trace_count == t0
    _assert_tree_equal(full, again)
