"""Report helpers: tidy rows, Pareto-front extraction, JSON/CSV export."""
import csv
import json

import pytest

from repro.dse import format_table, pareto_front, tidy, to_csv, to_json

ROWS = [
    {"lat": 10.0, "hit": 0.0, "time": 500.0},
    {"lat": 10.0, "hit": 0.8, "time": 200.0},
    {"lat": 40.0, "hit": 0.0, "time": 900.0},
    {"lat": 40.0, "hit": 0.8, "time": 350.0},
]


def test_pareto_front_min_time_max_lat_min_hit():
    # cheaper memory (higher lat) and smaller cache (lower hit) trade
    # against runtime: only the all-worse point is dominated
    front = pareto_front(ROWS, {"time": "min", "lat": "max", "hit": "min"})
    assert ROWS[0] in front and ROWS[1] in front and ROWS[3] in front
    # (lat=40, hit=0.8) beats nothing? it's the only lat-40 cheap-time point
    assert len(front) == 4 or ROWS[2] in front  # row2: worst time, best hit
    # single objective: unique minimum
    front_t = pareto_front(ROWS, {"time": "min"})
    assert front_t == [ROWS[1]]


def test_pareto_front_drops_dominated_and_duplicate_rows():
    rows = [{"a": 1.0, "b": 1.0}, {"a": 2.0, "b": 2.0},  # dominated
            {"a": 1.0, "b": 1.0}]                        # duplicate
    front = pareto_front(rows, {"a": "min", "b": "min"})
    assert front == [{"a": 1.0, "b": 1.0}]


def test_pareto_front_single_row():
    rows = [{"a": 3.0, "note": "only"}]
    assert pareto_front(rows, {"a": "min"}) == rows
    assert pareto_front(rows, {"a": "max"}) == rows


def test_pareto_front_tied_points_keep_first_occurrence():
    # distinct configs, identical objective vectors: the tie is resolved
    # to the first row in input order (stable, no double-reporting)
    rows = [{"a": 1.0, "b": 2.0, "cfg": "x"},
            {"a": 1.0, "b": 2.0, "cfg": "y"},
            {"a": 2.0, "b": 1.0, "cfg": "z"}]
    front = pareto_front(rows, {"a": "min", "b": "min"})
    assert front == [rows[0], rows[2]]


def test_pareto_front_excludes_nan_metrics():
    nan = float("nan")
    rows = [{"a": 1.0, "b": 5.0}, {"a": nan, "b": 0.0},   # NaN objective
            {"a": 2.0, "b": nan}, {"a": 3.0, "b": 1.0}]
    front = pareto_front(rows, {"a": "min", "b": "min"})
    # NaN rows neither appear on the front nor shield dominated rows
    assert front == [rows[0], rows[3]]
    # all-NaN input: empty front rather than everything "non-dominated"
    assert pareto_front([{"a": nan}, {"a": nan}], {"a": "min"}) == []


def test_tidy_unions_keys_and_coerces_scalars():
    import numpy as np
    rows = [{"a": np.float32(1.5)}, {"a": 2, "b": np.int32(7)}]
    t = tidy(rows)
    assert t == [{"a": 1.5, "b": None}, {"a": 2, "b": 7}]
    assert isinstance(t[0]["a"], float) and isinstance(t[1]["b"], int)


def test_json_and_csv_roundtrip(tmp_path):
    jp, cp = tmp_path / "r.json", tmp_path / "r.csv"
    to_json(ROWS, str(jp))
    assert json.loads(jp.read_text()) == tidy(ROWS)
    to_csv(ROWS, str(cp))
    with open(cp) as fh:
        back = list(csv.DictReader(fh))
    assert [float(r["time"]) for r in back] == [r["time"] for r in ROWS]


def test_format_table_lines_up():
    txt = format_table(ROWS)
    lines = txt.splitlines()
    assert lines[0].split() == ["lat", "hit", "time"]
    assert len(lines) == 2 + len(ROWS)
    assert len({len(ln) for ln in lines}) == 1   # fixed width
