"""Report helpers: tidy rows, Pareto-front extraction, JSON/CSV export."""
import csv
import json

import pytest

from repro.dse import dominates, format_table, pareto_front, tidy, \
    to_csv, to_json

ROWS = [
    {"lat": 10.0, "hit": 0.0, "time": 500.0},
    {"lat": 10.0, "hit": 0.8, "time": 200.0},
    {"lat": 40.0, "hit": 0.0, "time": 900.0},
    {"lat": 40.0, "hit": 0.8, "time": 350.0},
]


def test_pareto_front_min_time_max_lat_min_hit():
    # cheaper memory (higher lat) and smaller cache (lower hit) trade
    # against runtime: only the all-worse point is dominated
    front = pareto_front(ROWS, {"time": "min", "lat": "max", "hit": "min"})
    assert ROWS[0] in front and ROWS[1] in front and ROWS[3] in front
    # (lat=40, hit=0.8) beats nothing? it's the only lat-40 cheap-time point
    assert len(front) == 4 or ROWS[2] in front  # row2: worst time, best hit
    # single objective: unique minimum
    front_t = pareto_front(ROWS, {"time": "min"})
    assert front_t == [ROWS[1]]


def test_pareto_front_drops_dominated_and_duplicate_rows():
    rows = [{"a": 1.0, "b": 1.0}, {"a": 2.0, "b": 2.0},  # dominated
            {"a": 1.0, "b": 1.0}]                        # duplicate
    front = pareto_front(rows, {"a": "min", "b": "min"})
    assert front == [{"a": 1.0, "b": 1.0}]


def test_pareto_front_single_row():
    rows = [{"a": 3.0, "note": "only"}]
    assert pareto_front(rows, {"a": "min"}) == rows
    assert pareto_front(rows, {"a": "max"}) == rows


def test_pareto_front_tied_points_keep_first_occurrence():
    # distinct configs, identical objective vectors: the tie is resolved
    # to the first row in input order (stable, no double-reporting)
    rows = [{"a": 1.0, "b": 2.0, "cfg": "x"},
            {"a": 1.0, "b": 2.0, "cfg": "y"},
            {"a": 2.0, "b": 1.0, "cfg": "z"}]
    front = pareto_front(rows, {"a": "min", "b": "min"})
    assert front == [rows[0], rows[2]]


def test_pareto_front_excludes_nan_metrics():
    nan = float("nan")
    rows = [{"a": 1.0, "b": 5.0}, {"a": nan, "b": 0.0},   # NaN objective
            {"a": 2.0, "b": nan}, {"a": 3.0, "b": 1.0}]
    front = pareto_front(rows, {"a": "min", "b": "min"})
    # NaN rows neither appear on the front nor shield dominated rows
    assert front == [rows[0], rows[3]]
    # all-NaN input: empty front rather than everything "non-dominated"
    assert pareto_front([{"a": nan}, {"a": nan}], {"a": "min"}) == []


def test_dominates_respects_directions_and_nan():
    obj = {"t": "min", "q": "max"}
    assert dominates({"t": 1.0, "q": 5.0}, {"t": 2.0, "q": 5.0}, obj)
    assert dominates({"t": 1.0, "q": 6.0}, {"t": 2.0, "q": 5.0}, obj)
    assert not dominates({"t": 1.0, "q": 4.0}, {"t": 2.0, "q": 5.0}, obj)
    assert not dominates({"t": 1.0, "q": 5.0}, {"t": 1.0, "q": 5.0}, obj)
    nan = float("nan")
    assert not dominates({"t": nan, "q": 9.0}, {"t": 2.0, "q": 5.0}, obj)
    assert not dominates({"t": 1.0, "q": 9.0}, {"t": nan, "q": 5.0}, obj)


def _naive_front(rows, objectives):
    """The all-pairs O(n^2) reference the fast path must reproduce."""
    def score(r):
        return tuple((1.0 if d == "max" else -1.0) * float(r[c])
                     for c, d in objectives.items())
    scored = [(s, i) for i, r in enumerate(rows)
              for s in [score(r)] if not any(v != v for v in s)]
    front = []
    for s, i in scored:
        dominated = any(
            all(o >= v for o, v in zip(os, s))
            and any(o > v for o, v in zip(os, s))
            for os, j in scored if j != i)
        duplicate = any(os == s for os, j in front)
        if not dominated and not duplicate:
            front.append((s, i))
    return [dict(rows[i]) for _, i in front]


@pytest.mark.parametrize("objectives", [
    {"a": "min", "b": "min"},
    {"a": "min", "b": "max", "c": "min"},
])
def test_pareto_front_matches_naive_on_1k_rows(objectives):
    """The sort-based fast path is front-identical to the all-pairs
    implementation — same rows, same (input) order — on 1k rows with
    plenty of ties, duplicates and a few NaNs."""
    import numpy as np
    rng = np.random.default_rng(42)
    # few distinct values per column => heavy ties and exact duplicates
    rows = [{"a": float(rng.integers(0, 12)),
             "b": float(rng.integers(0, 12)),
             "c": float(rng.integers(0, 12)),
             "id": i} for i in range(1000)]
    for i in (17, 400, 999):
        rows[i]["a"] = float("nan")
    fast = pareto_front(rows, objectives)
    naive = _naive_front(rows, objectives)
    assert fast == naive
    ids = [r["id"] for r in fast]
    assert ids == sorted(ids)               # input order preserved


def test_tidy_unions_keys_and_coerces_scalars():
    import numpy as np
    rows = [{"a": np.float32(1.5)}, {"a": 2, "b": np.int32(7)}]
    t = tidy(rows)
    assert t == [{"a": 1.5, "b": None}, {"a": 2, "b": 7}]
    assert isinstance(t[0]["a"], float) and isinstance(t[1]["b"], int)


def test_json_and_csv_roundtrip(tmp_path):
    jp, cp = tmp_path / "r.json", tmp_path / "r.csv"
    to_json(ROWS, str(jp))
    assert json.loads(jp.read_text()) == tidy(ROWS)
    to_csv(ROWS, str(cp))
    with open(cp) as fh:
        back = list(csv.DictReader(fh))
    assert [float(r["time"]) for r in back] == [r["time"] for r in ROWS]


def test_format_table_lines_up():
    txt = format_table(ROWS)
    lines = txt.splitlines()
    assert lines[0].split() == ["lat", "hit", "time"]
    assert len(lines) == 2 + len(ROWS)
    assert len({len(ln) for ln in lines}) == 1   # fixed width
