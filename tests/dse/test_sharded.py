"""Sharded sweep rounds over the device mesh (DSE.md "Sharded sweeps
and the persistent cache").

The hard bar: ``shard=True`` must be a pure *placement* decision — every
row of ``run_batch`` / ``run_rounds`` / ``run_sweep`` / ``run_search``
bit-identical to the single-device vmap path, on every memsys pattern,
on masked family lanes and on mixed-horizon batches.  Multi-device
behavior (the mesh itself, non-divisible-batch padding, global
rebalancing) is only reachable with >1 device, so those tests run in a
subprocess with forced host devices, like the ``test_runner.py`` one.

Single-device properties (the `shard` argument's normalization, the
per-topology autotune slot, mesh-aligned ladders) are tested inline.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.dse import BatchRunner, build_param_batch, stack_states
from repro.dse.runner import _align_up, _shard_devices
from repro.sims.memsys import build

_TWO_DEV_ENV = dict(
    XLA_FLAGS="--xla_force_host_platform_device_count=2")


def _run_two_device(script: str, timeout: int = 900):
    root = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.update(_TWO_DEV_ENV)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    return r.stdout


# ---------------------------------------------------------------------------
# inline (single-device) contracts
# ---------------------------------------------------------------------------
def test_shard_devices_normalization():
    n = jax.local_device_count()
    assert _shard_devices(False) == 1
    assert _shard_devices(0) == 1
    assert _shard_devices(None) == 1
    assert _shard_devices(True) == n
    assert _shard_devices(1) == 1
    assert _shard_devices(999) == n          # clamped to the host
    assert _align_up(65, 2) == 66 and _align_up(64, 2) == 64
    assert _align_up(5, 1) == 5


def test_tuned_top_keyed_on_device_count_not_shard_flag():
    """shard=False and shard=1 are the same topology (one device) and
    must share the autotuned rung slot; a different mesh width gets its
    own slot — a runner reused under a different device count must not
    inherit a stale chunk rung."""
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=6, donate=False)
    r = BatchRunner(sim)
    r._tuned_top[1] = 8          # pretend a 1-device autotune ran
    B = 16
    pb = build_param_batch(
        sim, [{"conn_latency[-1]": float(10 + i)} for i in range(B)])
    r.run_rounds(st, pb, 2000.0, shard=False)
    assert r.last_rounds["chunk"] == 8       # consumed the d=1 slot
    r.run_rounds(st, pb, 2000.0, shard=1)
    assert r.last_rounds["chunk"] == 8       # same slot, no re-probe
    assert set(r._tuned_top) == {1}          # nothing keyed on bools
    assert all(isinstance(k, int) for k in r._tuned_top)


def test_single_device_shard_rows_identical():
    """With one device, shard=True routes through the same plain-vmap
    executable — byte-identical results and a shared executable cache."""
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=6, donate=False)
    r = BatchRunner(sim)
    pb = build_param_batch(
        sim, [{"conn_latency[-1]": float(v)} for v in (10, 20, 30)])
    a = r.run_batch(stack_states(st, 3), pb, 20000.0, shard=False)
    n_fns = len(r._fns)
    b = r.run_batch(stack_states(st, 3), pb, 20000.0,
                    shard=jax.local_device_count())
    if jax.local_device_count() == 1:
        assert len(r._fns) == n_fns          # same (3, 1) executable
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# the 2-device mesh: bit-identity across every layer + padding
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_rounds_sweep_search_bit_identical_two_devices():
    """One subprocess, four layers: (1) ``run_rounds`` on a B=65
    mixed-horizon batch — bit-identical to monolithic ``run_batch`` and
    padded to 66 so *both* devices run 33 lanes (no largest-divisor
    fallback); (2) ``run_sweep`` over all five memsys patterns as
    static groups with mixed horizons; (3) masked family lanes
    (``shape.core``); (4) a seeded halving ``run_search`` — all rows
    bit-identical between shard=True and the vmap path."""
    out = _run_two_device("""
        import jax, numpy as np
        assert jax.local_device_count() == 2
        from repro.dse import (BatchRunner, Objective, SuccessiveHalving,
                               SweepSpec, build_param_batch, run_search,
                               run_sweep, stack_states)
        from repro.sims.memsys import build, build_family

        # ---- 1. rounds, B=65 (odd: padding must engage), ~8x spread
        sim, st = build(n_cores=2, pattern="mixed", n_reqs=6,
                        donate=False)
        B = 65
        pts = [{"conn_latency[-1]": float(10 + (i % 7) * 5)}
               for i in range(B)]
        pb = build_param_batch(sim, pts)
        u = np.linspace(400.0, 3200.0, B).astype(np.float32)
        r = BatchRunner(sim)
        ref = r.run_batch(stack_states(st, B), pb, u)
        out = r.run_rounds(st, pb, u, shard=True)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert r.last_shard == 2 and r.last_rounds["shard"] == 2
        # every sharded executable spans d=2 with an even batch: B=65
        # ran padded to 66, not shrunk to a divisor (65 is odd -> the
        # old pmap path would have collapsed to d=1)
        sharded = [k for k in r._fns
                   if isinstance(k[0], int) and k[1] == 2]
        assert sharded and all(k[0] % 2 == 0 for k in sharded), sharded
        mono = r.run_batch(stack_states(st, B), pb, u, shard=True)
        assert (66, 2) in r._fns and (65, 2) not in r._fns
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(mono)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ROUNDS_OK")

        # ---- 2. run_sweep: all five patterns x mixed horizons
        spec = SweepSpec.grid({
            "static.pattern": ["compute", "stream", "pointer",
                               "idle_half", "mixed"],
            "conn_latency[-1]": [10.0, 25.0],
            "kind.core.think_scale": [1.0, 1.5]})
        def bf(pattern="mixed"):
            return build(n_cores=2, pattern=pattern, n_reqs=6,
                         donate=False)
        u2 = np.linspace(500.0, 4000.0, len(spec)).astype(np.float32)
        assert run_sweep(bf, spec, until=u2) == \\
            run_sweep(bf, spec, until=u2, shard=True)
        print("SWEEP_OK")

        # ---- 3. masked family lanes (shape.core)
        fspec = SweepSpec.grid({"shape.core": [1, 2],
                                "kind.core.think_scale": [1.0, 1.4]})
        def fb(shape=None):
            return build_family(shape=shape, n_cores=2, pattern="mixed",
                                n_reqs=6, donate=False)
        fu = np.linspace(600.0, 2400.0, len(fspec)).astype(np.float32)
        assert run_sweep(fb, fspec, until=fu) == \\
            run_sweep(fb, fspec, until=fu, shard=True)
        print("FAMILY_OK")

        # ---- 4. search: same seeded trajectory under the mesh
        def search(shard):
            pool = SweepSpec.grid({
                "conn_latency[-1]": [10.0, 20.0, 30.0, 40.0],
                "kind.core.think_scale": [1.0, 1.5]})
            drv = SuccessiveHalving(
                pool, Objective("virtual_time"), max_horizon=2000.0,
                min_horizon=500.0, eta=2, seed=7)
            def bsearch():
                return build(n_cores=2, pattern="mixed", n_reqs=6,
                             donate=False)
            return run_search(bsearch, drv, shard=shard)
        a, b = search(False), search(True)
        assert a.rows == b.rows and a.best == b.best
        print("SEARCH_OK")
    """)
    for tag in ("ROUNDS_OK", "SWEEP_OK", "FAMILY_OK", "SEARCH_OK"):
        assert tag in out, out


@pytest.mark.slow
def test_sharded_rebalance_telemetry_two_devices():
    """Under the mesh, survivors re-pack globally each round; the
    ``shard.rebalance`` events must report the lanes that changed shard
    (and the rounds must still be bit-identical — covered above)."""
    out = _run_two_device("""
        import jax, numpy as np
        assert jax.local_device_count() == 2
        from repro.dse import BatchRunner, ChunkSchedule, \\
            build_param_batch
        from repro.obs.bus import capture
        from repro.sims.memsys import build
        sim, st = build(n_cores=2, pattern="mixed", n_reqs=48,
                        donate=False)
        B = 32
        pb = build_param_batch(
            sim, [{"conn_latency[-1]": float(10 + i)} for i in range(B)])
        # adversarial horizons: even lanes finish early, odd lanes run
        # long -- survivors compact into fresh shard layouts over many
        # small-quantum rounds, so some must land on the other shard
        u = np.where(np.arange(B) % 2 == 0, 300.0, 6000.0) \\
            .astype(np.float32)
        sched = ChunkSchedule(ladder=(16, 8), quantum=32,
                              min_round_s=0.0)
        with capture() as sink:
            BatchRunner(sim).run_rounds(st, pb, u, schedule=sched,
                                        shard=True)
        ev = [e for e in sink.events if e["kind"] == "shard.rebalance"]
        assert ev, "no shard.rebalance events under a 2-device mesh"
        assert all(e["shards"] == 2 for e in ev)
        assert sum(e["moved"] for e in ev) > 0, ev
        assert all(0 <= e["moved"] <= e["lanes"] for e in ev)
        rs = [e for e in sink.events if e["kind"] == "rounds.start"]
        assert rs and rs[0]["shard"] == 2
        # mesh-aligned ladder: every rung is even
        assert all(r % 2 == 0 for r in rs[0]["ladder"]), rs[0]
        print("REBALANCE_OK")
    """)
    assert "REBALANCE_OK" in out
