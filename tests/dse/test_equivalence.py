"""Singleton-batch equivalence: a B=1 ``repro.dse`` run must reproduce the
unbatched engine bit-for-bit (stat_err exactly 0) — the invariant that
makes batched sweep results trustworthy.  Pinned on all five memsys
workload patterns and the onira CPI benchmark, for default and overridden
params alike; plus: explicitly passing ``default_params()`` must match the
``params=None`` constant-baked path.
"""
import jax
import numpy as np
import pytest

from repro.dse import BatchRunner, build_param_batch, lane, stack_states
from repro.sims import onira
from repro.sims.memsys import build, finish_stats

PATTERNS = ["compute", "stream", "pointer", "idle_half", "mixed"]
STAT_FIELDS = ("epochs", "ticks", "progress_ticks", "delivered")


def assert_states_identical(a, b):
    assert float(a.time) == float(b.time)
    for f in STAT_FIELDS:
        assert int(getattr(a.stats, f)) == int(getattr(b.stats, f)), f
    np.testing.assert_array_equal(np.asarray(a.stats.busy),
                                  np.asarray(b.stats.busy))
    np.testing.assert_array_equal(np.asarray(a.next_tick),
                                  np.asarray(b.next_tick))
    for kname in a.comp_state:
        for la, lb in zip(jax.tree.leaves(a.comp_state[kname]),
                          jax.tree.leaves(b.comp_state[kname])):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for seg_a, seg_b in ((a.in_cnt, b.in_cnt), (a.out_cnt, b.out_cnt),
                         (a.in_buf, b.in_buf), (a.out_buf, b.out_buf)):
        for kname in seg_a:
            np.testing.assert_array_equal(np.asarray(seg_a[kname]),
                                          np.asarray(seg_b[kname]))


def singleton(sim, st, until, point):
    out_b = BatchRunner(sim).run_batch(
        stack_states(st, 1), build_param_batch(sim, [point]), until)
    return lane(out_b, 0)


@pytest.mark.parametrize("pattern", PATTERNS)
def test_memsys_singleton_matches_unbatched(pattern):
    sim, st = build(n_cores=4, pattern=pattern, n_reqs=12, donate=False)
    ref = sim.run(st, until=20000.0)
    out = singleton(sim, st, 20000.0, {})
    assert_states_identical(out, ref)
    assert finish_stats(sim, out)["remaining"] == 0   # not vacuous


def test_memsys_singleton_matches_unbatched_with_overrides():
    point = {"conn_latency[-1]": 17.0, "kind.l1.extra_hit_rate": 0.35,
             "period.dram": 2.0}
    sim, st = build(n_cores=4, pattern="mixed", n_reqs=12, donate=False)
    params = build_param_batch(sim, [point])
    ref = sim.run(st, until=20000.0, params=lane(params, 0))
    assert_states_identical(singleton(sim, st, 20000.0, point), ref)


def test_explicit_default_params_match_constant_baked_path():
    sim, st = build(n_cores=4, pattern="mixed", n_reqs=12, donate=False)
    baked = sim.run(st, until=20000.0)                       # params=None
    explicit = sim.run(st, until=20000.0, params=sim.default_params())
    assert_states_identical(explicit, baked)


def test_onira_cpi_singleton_matches_unbatched():
    names = list(onira.MICROBENCHES)
    progs = [onira.MICROBENCHES[n]() for n in names]
    sim, st = onira.build_onira(progs, mem_latency=5.0)
    ref = sim.run(sim.copy_state(st), until=20000.0)
    out = singleton(sim, st, 20000.0, {})
    assert_states_identical(out, ref)
    cs = np.asarray(out.comp_state["cpu"]["done"])
    assert cs.all()                                          # all halted
    # and the CPI values still track the analytic pipeline model
    retired = np.asarray(out.comp_state["cpu"]["retired"], np.float64)
    halt = np.asarray(out.comp_state["cpu"]["halt_time"], np.float64)
    for i, n in enumerate(names):
        cpi = halt[i] / max(retired[i], 1)
        ref_cpi = onira.analytic_cpi(n)
        assert abs(cpi - ref_cpi) / ref_cpi < 0.35, (n, cpi, ref_cpi)


def test_onira_flush_cycles_sweep_moves_cpi():
    progs = [onira.prog_br_loop(iters=16, body_n=4)]
    sim, st = onira.build_onira(progs, mem_latency=5.0)
    runner = BatchRunner(sim)
    pb = build_param_batch(sim, [{"kind.cpu.flush_cycles": v}
                                 for v in (3.0, 9.0)])
    out = runner.run_batch(stack_states(st, 2), pb, 20000.0)
    halt = np.asarray(out.comp_state["cpu"]["halt_time"])[:, 0]
    assert halt[1] > halt[0]      # costlier flush -> slower loop
