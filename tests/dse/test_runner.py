"""BatchRunner execution semantics: chunking, sharding, and the
donation/aliasing contract for batched states (a donated batch must never
alias across configs or with the template state)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dse import (BatchRunner, SweepSpec, build_param_batch, lane,
                       run_sweep, stack_states)
from repro.sims.memsys import build, finish_stats

POINTS = [{"conn_latency[-1]": float(v)} for v in (10, 20, 30, 40, 50)]


def _build(**kw):
    return build(n_cores=3, pattern="mixed", n_reqs=6, **kw)


def test_chunked_equals_unchunked_including_padded_tail():
    sim, st = _build(donate=False)
    pb = build_param_batch(sim, POINTS)                     # B=5
    runner = BatchRunner(sim)
    whole = runner.run_chunked(st, pb, until=20000.0)       # one chunk
    split = runner.run_chunked(st, pb, until=20000.0, chunk=2)  # 2+2+pad
    for a, b in zip(jax.tree.leaves(whole), jax.tree.leaves(split)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shard_flag_runs_and_matches_plain_vmap():
    sim, st = _build(donate=False)
    pb = build_param_batch(sim, POINTS[:4])
    runner = BatchRunner(sim)
    plain = runner.run_batch(stack_states(st, 4), pb, 20000.0)
    shard = runner.run_batch(stack_states(st, 4), pb, 20000.0, shard=True)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(shard)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_shard_maps_over_multiple_devices():
    """The shard_map mesh path (only reachable with >1 device, hence the
    subprocess with forced host devices) must match plain vmap
    bit-for-bit.  tests/dse/test_sharded.py covers the rounds/sweep/
    search layers and the non-divisible-batch padding on the same mesh."""
    root = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, numpy as np
        assert jax.local_device_count() == 2
        from repro.dse import BatchRunner, build_param_batch, stack_states
        from repro.sims.memsys import build
        sim, st = build(n_cores=2, pattern="mixed", n_reqs=6, donate=False)
        pb = build_param_batch(
            sim, [{"conn_latency[-1]": float(v)} for v in (10, 20, 30, 40)])
        r = BatchRunner(sim)
        plain = r.run_batch(stack_states(st, 4), pb, 20000.0)
        shard = r.run_batch(stack_states(st, 4), pb, 20000.0, shard=True)
        for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(shard)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """)], capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# satellite: copy_state / donate=False interplay with vmapped batched runs
# ---------------------------------------------------------------------------
def test_stack_states_does_not_alias_template_or_lanes():
    sim, st = _build(donate=True)
    sb = stack_states(st, 3)
    pb = build_param_batch(sim, POINTS[:3])
    out = BatchRunner(sim).run_batch(sb, pb, 20000.0)
    # batch was donated...
    assert sb.next_tick.is_deleted()
    assert all(v.is_deleted() for v in sb.in_buf.values())
    # ...but the template survives and is itself still runnable
    assert not st.next_tick.is_deleted()
    assert all(not v.is_deleted() for v in st.in_buf.values())
    ref = sim.run(st, until=20000.0)     # donates st; out must be unaffected
    assert float(ref.time) > 0.0
    # distinct params produced distinct lanes (no cross-config aliasing)
    times = [float(lane(out, i).time) for i in range(3)]
    assert len(set(times)) == 3, times


def test_identical_lanes_stay_bitwise_identical():
    sim, st = _build(donate=True)
    pb = build_param_batch(sim, [{}, {}])       # same config twice
    out = BatchRunner(sim).run_batch(stack_states(st, 2), pb, 20000.0)
    for leaf in jax.tree.leaves(out):
        a = np.asarray(leaf)
        np.testing.assert_array_equal(a[0], a[1])


def test_copy_state_makes_batched_input_survive_donation():
    sim, st = _build(donate=True)
    runner = BatchRunner(sim)
    pb = build_param_batch(sim, POINTS[:2])
    sb = stack_states(st, 2)
    keep = sim.copy_state(sb)                   # batched deep copy
    out1 = runner.run_batch(sb, pb, 20000.0)
    assert sb.next_tick.is_deleted()
    assert not keep.next_tick.is_deleted()
    out2 = runner.run_batch(keep, pb, 20000.0)  # replay from the copy
    for a, b in zip(jax.tree.leaves(out1), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_consumed_batch_reuse_raises_clear_error():
    """Reusing a donated batch must fail with an actionable message, not
    XLA's opaque deleted-buffer error."""
    sim, st = _build(donate=True)
    runner = BatchRunner(sim)
    pb = build_param_batch(sim, POINTS[:2])
    sb = stack_states(st, 2)
    runner.run_batch(sb, pb, 1000.0)
    with pytest.raises(RuntimeError, match="copy_state"):
        runner.run_batch(sb, pb, 1000.0)
    with pytest.raises(RuntimeError, match="donate=False"):
        sim.run(sb, until=1000.0)


def test_donate_false_build_keeps_batched_input_reusable():
    sim, st = _build(donate=False)
    runner = BatchRunner(sim)
    pb = build_param_batch(sim, POINTS[:2])
    sb = stack_states(st, 2)
    out1 = runner.run_batch(sb, pb, 20000.0)
    assert not sb.next_tick.is_deleted()
    out2 = runner.run_batch(sb, pb, 20000.0)    # same input, second run
    for a, b in zip(jax.tree.leaves(out1), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
def test_run_sweep_rows_in_spec_order_across_static_groups():
    spec = SweepSpec.grid({"conn_latency[-1]": [10.0, 40.0],
                           "static.super_epoch": [1, 4]})

    def extract(sim, s):
        return {"virtual_time": float(s.time),
                "remaining": finish_stats(sim, s)["remaining"]}

    rows = run_sweep(lambda **kw: _build(donate=True, **kw), spec,
                     until=20000.0, extract=extract)
    assert [r["conn_latency[-1]"] for r in rows] == [10.0, 10.0, 40.0, 40.0]
    assert [r["static.super_epoch"] for r in rows] == [1, 4, 1, 4]
    assert all(r["remaining"] == 0 for r in rows)
    # super_epoch is an observation-invariant perf knob; latency is not
    assert rows[0]["virtual_time"] == rows[1]["virtual_time"]
    assert rows[2]["virtual_time"] == rows[3]["virtual_time"]
    assert rows[2]["virtual_time"] > rows[0]["virtual_time"]
