"""SweepSpec construction, static/traced splitting and param application."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dse import SweepSpec, apply_point, build_param_batch, stack_params
from repro.sims.memsys import build


@pytest.fixture(scope="module")
def sim():
    s, _ = build(n_cores=2, pattern="mixed", n_reqs=4, donate=False)
    return s


def test_grid_is_cartesian_product_in_order():
    spec = SweepSpec.grid({"a": [1, 2], "b": [10, 20, 30]})
    assert len(spec) == 6
    assert spec.points[0] == {"a": 1, "b": 10}
    assert spec.points[1] == {"a": 1, "b": 20}   # last axis fastest
    assert spec.points[-1] == {"a": 2, "b": 30}


def test_random_is_seeded_and_in_bounds():
    axes = {"u": (2.0, 8.0), "l": (1.0, 100.0, "log"), "c": [4, 8, 16, "x"]}
    s1 = SweepSpec.random(axes, n=32, seed=7)
    s2 = SweepSpec.random(axes, n=32, seed=7)
    assert s1.points == s2.points                # deterministic
    for p in s1:
        assert 2.0 <= p["u"] <= 8.0
        assert 1.0 <= p["l"] <= 100.0
        assert p["c"] in (4, 8, 16, "x")
    assert len({p["u"] for p in s1}) > 1         # actually samples


def test_random_per_axis_streams_survive_style_and_order_changes():
    """Axis substreams are keyed on (seed, name): the values axis "u"
    yields must be identical whether its neighbour is a choice list or a
    (lo, hi) range, and whatever the dict order or axis count."""
    u_alone = [p["u"] for p in SweepSpec.random({"u": (2.0, 8.0)}, 16,
                                                seed=3)]
    with_choice = SweepSpec.random({"u": (2.0, 8.0), "c": [4, 8, 16]},
                                   16, seed=3)
    with_range = SweepSpec.random({"c": (0.0, 1.0), "u": (2.0, 8.0)},
                                  16, seed=3)
    assert [p["u"] for p in with_choice] == u_alone
    assert [p["u"] for p in with_range] == u_alone
    assert [p["c"] for p in with_choice] != [p["c"] for p in with_range]


def test_random_int_axes_come_back_as_python_ints():
    import numpy as np
    spec = SweepSpec.random({"r": (2, 8),                  # int range
                             "c": [1, 2, 4, 8],            # int choice
                             "n": [np.int32(3), np.int32(5), np.int32(9)],
                             "f": (2.0, 8.0)}, 32, seed=11)
    for p in spec:
        assert type(p["r"]) is int and 2 <= p["r"] <= 8    # inclusive
        assert type(p["c"]) is int and p["c"] in (1, 2, 4, 8)
        assert type(p["n"]) is int and p["n"] in (3, 5, 9)
        assert type(p["f"]) is float
    assert {p["r"] for p in spec} == set(range(2, 9))      # hits both ends
    # same-seed determinism holds for every style
    assert spec.points == SweepSpec.random(
        {"r": (2, 8), "c": [1, 2, 4, 8],
         "n": [np.int32(3), np.int32(5), np.int32(9)],
         "f": (2.0, 8.0)}, 32, seed=11).points


def test_explicit_rejects_ragged_points_naming_index_and_keys():
    with pytest.raises(ValueError) as e:
        SweepSpec.explicit([{"a": 1.0, "b": 2.0},
                            {"a": 1.0, "b": 2.0},
                            {"a": 3.0, "c": 4.0}])
    msg = str(e.value)
    assert "point 2" in msg                 # the offending index
    assert "'b'" in msg and "'c'" in msg    # missing and extra keys
    # uniform points construct fine
    SweepSpec.explicit([{"a": 1.0}, {"a": 2.0}])
    # different static groups stack separately, so their traced axes
    # may legitimately differ — no ragged=True needed
    spec = SweepSpec.explicit([{"static.x": 1, "a": 1.0},
                               {"static.x": 2, "b": 2.0}])
    assert len(spec) == 2
    # ...but raggedness *within* one static group still raises
    with pytest.raises(ValueError, match="point 1"):
        SweepSpec.explicit([{"static.x": 1, "a": 1.0},
                            {"static.x": 1, "b": 2.0}])
    # and ragged=True skips the check entirely
    SweepSpec.explicit([{"static.x": 1, "a": 1.0},
                        {"static.x": 1, "b": 2.0}], ragged=True)


def test_split_static_groups_and_preserves_indices():
    spec = SweepSpec.grid({"static.super_epoch": [1, 4],
                           "conn_latency": [5.0, 9.0]})
    groups = spec.split_static()
    assert len(groups) == 2
    (st1, ix1, tr1), (st2, ix2, tr2) = groups
    assert st1 == {"super_epoch": 1} and st2 == {"super_epoch": 4}
    assert ix1 == [0, 1] and ix2 == [2, 3]
    assert tr1 == [{"conn_latency": 5.0}, {"conn_latency": 9.0}] == tr2


def test_apply_point_paths(sim):
    base = sim.default_params()
    p = apply_point(base, {"conn_latency": 7.0})
    assert np.all(np.asarray(p.conn_latency) == 7.0)
    p = apply_point(base, {"conn_latency[-1]": 50.0})
    np.testing.assert_array_equal(
        np.asarray(p.conn_latency[:-1]), np.asarray(base.conn_latency[:-1]))
    assert float(p.conn_latency[-1]) == 50.0
    p = apply_point(base, {"period.dram": 4.0, "period.core[0]": 2.0})
    assert float(p.periods["dram"][0]) == 4.0
    assert float(p.periods["core"][0]) == 2.0
    assert float(p.periods["core"][1]) == 1.0
    p = apply_point(base, {"kind.l1.extra_hit_rate": 0.5})
    assert float(p.kind["l1"]["extra_hit_rate"]) == 0.5
    # base is never mutated
    assert float(base.kind["l1"]["extra_hit_rate"]) == 0.0
    assert np.all(np.asarray(base.periods["dram"]) == 1.0)


@pytest.mark.parametrize("bad", [
    {"nope": 1.0},
    {"period.nokind": 1.0},
    {"kind.l1.nope": 1.0},
    {"kind.nokind.x": 1.0},
    {"static.super_epoch": 2},       # static must not reach apply_point
])
def test_apply_point_rejects_unknown_paths(sim, bad):
    with pytest.raises(KeyError):
        apply_point(sim.default_params(), bad)


def test_apply_point_rejects_out_of_range_index(sim):
    with pytest.raises(AssertionError):
        apply_point(sim.default_params(), {"conn_latency[99]": 2.0})


# ---------------------------------------------------------------------------
# eager axis-path validation (no deep KeyError mid-run_sweep)
# ---------------------------------------------------------------------------
def test_validate_names_bad_path_and_valid_axes(sim):
    spec = SweepSpec.grid({"period.l1x": [1.0, 2.0]})
    with pytest.raises(ValueError) as e:
        spec.validate(sim)
    msg = str(e.value)
    assert "period.l1x" in msg          # the bad path, by name
    assert "period.l1" in msg           # ...and the valid alternatives
    assert "kind.l1.extra_hit_rate" in msg


def test_validate_at_construction_via_validate_for(sim):
    with pytest.raises(ValueError, match="nope"):
        SweepSpec.grid({"nope": [1.0]}, validate_for=sim)
    with pytest.raises(ValueError, match="kind.l1.no_leaf"):
        SweepSpec.explicit([{"kind.l1.no_leaf": 0.5}], validate_for=sim)
    with pytest.raises(ValueError, match="out of range"):
        SweepSpec.random({"conn_latency[99]": (1.0, 2.0)}, n=2,
                         validate_for=sim)


def test_validate_accepts_every_documented_axis_form(sim):
    spec = SweepSpec.explicit([{
        "conn_latency": 2.0, "conn_latency[-1]": 10.0,
        "period.dram": 2.0, "period.core[0]": 2.0,
        "kind.l1.extra_hit_rate": 0.5,
        "static.super_epoch": 2}])
    assert spec.validate(sim) is spec                   # chains
    # static axes are checked only against an explicit whitelist
    spec.validate(sim, static_ok=["super_epoch"])
    with pytest.raises(ValueError, match="super_epoch"):
        spec.validate(sim, static_ok=["other_kwarg"])


def test_run_sweep_raises_eagerly_on_unknown_traced_axis(sim):
    from repro.dse import run_sweep
    from repro.sims.memsys import build
    spec = SweepSpec.grid({"period.l1x": [1.0, 2.0]})
    with pytest.raises(ValueError, match="period.l1x"):
        run_sweep(lambda: build(n_cores=2, pattern="mixed", n_reqs=4),
                  spec, until=100.0)


def test_run_sweep_rejects_unknown_static_kwarg_before_building():
    from repro.dse import run_sweep
    builds = []

    def build_fn(super_epoch=None):
        builds.append(super_epoch)
        raise AssertionError("must not build")

    spec = SweepSpec.grid({"static.super_epok": [1]})
    with pytest.raises(ValueError, match="super_epok"):
        run_sweep(build_fn, spec, until=100.0)
    assert builds == []


def test_run_sweep_validates_each_static_group_against_its_own_build():
    """Axis paths are checked per compile group: an index that is only
    valid for the larger topology must not fail against the smaller
    group's sim (and vice versa must still be caught)."""
    from repro.dse import run_sweep
    from repro.sims.memsys import build

    def build_fn(n_cores):
        return build(n_cores=n_cores, pattern="mixed", n_reqs=4,
                     donate=False)

    # n_cores=2 -> 3 connections, n_cores=3 -> 4: conn_latency[3] only
    # exists in the second group
    spec = SweepSpec.explicit([
        {"static.n_cores": 2, "conn_latency[-1]": 10.0},
        {"static.n_cores": 3, "conn_latency[3]": 10.0}])
    rows = run_sweep(build_fn, spec, until=20000.0)
    assert len(rows) == 2 and all(r["epochs"] > 0 for r in rows)

    bad = SweepSpec.explicit([{"static.n_cores": 2, "conn_latency[3]": 1.0}])
    with pytest.raises(ValueError, match="out of range"):
        run_sweep(build_fn, bad, until=100.0)


def test_split_shape_strips_prefix():
    from repro.dse import split_shape
    shape, traced = split_shape({"shape.core": 4, "conn_latency": 5.0})
    assert shape == {"core": 4}
    assert traced == {"conn_latency": 5.0}


def test_apply_point_rejects_shape_axes(sim):
    with pytest.raises(KeyError, match="TopologyFamily"):
        apply_point(sim.default_params(), {"shape.core": 2})


def test_stack_params_shapes(sim):
    spec = SweepSpec.grid({"conn_latency[-1]": [10.0, 20.0, 40.0]})
    pb = build_param_batch(sim, list(spec))
    assert pb.conn_latency.shape == (3,) + sim.default_params().conn_latency.shape
    assert pb.periods["core"].shape[0] == 3
    np.testing.assert_array_equal(
        np.asarray(pb.conn_latency[:, -1]), [10.0, 20.0, 40.0])
    # non-swept leaves are identical across the batch
    assert np.all(np.asarray(pb.kind["l1"]["extra_hit_rate"]) == 0.0)
