"""Cross-job lane multiplexing (DSE.md "Multiplexing jobs into shared
batches").

The contract: a multiplexed job's rows are exactly its solo-run rows —
sharing rounds, rungs and executables with other jobs changes nothing
about any job's results — and refill is fair (round-robin point
interleave, so no job waits behind the whole of another).
"""
import numpy as np
import pytest

from repro.dse import LaneMux, SweepSpec, run_sweep, runner_for
from repro.dse.mux import MUX_AXIS, MuxJob
from repro.obs.bus import capture
from repro.sims.memsys import build


def _build_a():
    return build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)


def _build_b():
    return build(n_cores=2, pattern="stream", n_reqs=6, donate=True)


SPEC_A = SweepSpec.explicit(
    [{"conn_latency[-1]": float(v)} for v in (10, 25, 40)])
SPEC_B = SweepSpec.explicit(
    [{"conn_latency[-1]": float(v)} for v in (12, 30)])


# ---------------------------------------------------------------------------
def test_two_jobs_shared_build_rows_identical_to_solo():
    """Two interleaved jobs over the same topology produce exactly the
    rows each would produce alone — including per-job mixed horizons."""
    u_a = [300.0, 1200.0, 600.0]
    u_b = [900.0, 150.0]
    solo_a = run_sweep(_build_a, SPEC_A, u_a, chunk=2)
    solo_b = run_sweep(_build_a, SPEC_B, u_b, chunk=2)

    mux = LaneMux()
    mux.submit("a", _build_a, SPEC_A, u_a)
    mux.submit("b", _build_a, SPEC_B, u_b)
    got = mux.run(chunk=2)
    assert set(got) == {"a", "b"}
    assert got["a"] == solo_a
    assert got["b"] == solo_b


def test_two_jobs_different_builds_routed_and_identical():
    """Jobs over *different* topologies multiplex too (the reserved
    routing axis keeps their compile groups apart) and the axis never
    leaks into result rows."""
    solo_a = run_sweep(_build_a, SPEC_A, 500.0)
    solo_b = run_sweep(_build_b, SPEC_B, [250.0, 800.0])

    mux = LaneMux()
    mux.submit("a", _build_a, SPEC_A, 500.0)
    mux.submit("b", _build_b, SPEC_B, [250.0, 800.0])
    got = mux.run()
    assert got["a"] == solo_a
    assert got["b"] == solo_b
    for rows in got.values():
        assert all(MUX_AXIS not in r for r in rows)


def test_jobs_share_one_compile_group_and_rounds():
    """Same build + same static axes -> one sweep group: the jobs'
    lanes really ride shared batches (one sweep.group event), and the
    mux telemetry brackets the run."""
    mux = LaneMux()
    mux.submit("a", _build_a, SPEC_A, 400.0)
    mux.submit("b", _build_a, SPEC_B, 700.0)
    with capture() as sink:
        mux.run(chunk=2)
    groups = [e for e in sink.events if e["kind"] == "sweep.group"]
    assert len(groups) == 1
    assert groups[0]["n_points"] == len(SPEC_A) + len(SPEC_B)
    kinds = [e["kind"] for e in sink.events]
    assert kinds[0] == "mux.start" and kinds[-1] == "mux.end"


def test_interleave_is_round_robin_fair():
    order = LaneMux._interleave([
        MuxJob("a", _build_a, SPEC_A, 1.0),     # 3 points
        MuxJob("b", _build_a, SPEC_B, 1.0),     # 2 points
    ])
    assert order == [(0, 0), (1, 0), (0, 1), (1, 1), (0, 2)]


def test_per_job_extractors_and_custom_rows():
    def ex_a(sim, lane_state):
        return {"t": float(lane_state.time)}

    mux = LaneMux()
    mux.submit("a", _build_a, SPEC_A, 400.0, extract=ex_a)
    mux.submit("b", _build_a, SPEC_B, 400.0)
    got = mux.run(chunk=2)
    assert all(set(r) == {"conn_latency[-1]", "t"} for r in got["a"])
    assert all("epochs" in r for r in got["b"])     # default extractor


def test_reserved_axis_and_duplicate_job_id_rejected():
    bad = SweepSpec.explicit([{MUX_AXIS: 0, "conn_latency[-1]": 5.0}],
                             ragged=True)
    mux = LaneMux()
    with pytest.raises(ValueError, match="reserved"):
        mux.submit("a", _build_a, bad, 100.0)
    mux.submit("a", _build_a, SPEC_A, 100.0)
    with pytest.raises(ValueError, match="duplicate"):
        mux.submit("a", _build_a, SPEC_B, 100.0)


def test_mux_adds_no_recompiles_over_solo():
    """Multiplexing same-build jobs reuses the solo runs' executables:
    after a solo warmup at the same rungs, a mux run retraces nothing."""
    from repro.dse import memoize_build
    mb = memoize_build(_build_a)
    run_sweep(mb, SPEC_A, 400.0, chunk=2)
    run_sweep(mb, SPEC_B, 700.0, chunk=2)
    sim, _ = mb()
    warm = runner_for(sim).trace_count
    mux = LaneMux()
    mux.submit("a", mb, SPEC_A, 400.0)
    mux.submit("b", mb, SPEC_B, 700.0)
    mux.run(chunk=2)
    assert runner_for(sim).trace_count == warm
