"""The campaign cache (``repro.dse.cache``): artifact store semantics,
key invalidation, telemetry, and the headline contract — the second
process of a campaign performs **zero** XLA compiles (every executable
deserializes from the shared persistent compilation cache).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.dse import cache as dse_cache
from repro.dse.cache import DseCache
from repro.obs.bus import capture
from repro.sims.memsys import build


@pytest.fixture()
def cache_dir(tmp_path):
    """A configured campaign cache dir, unconfigured again on exit (the
    module is process-global state)."""
    d = str(tmp_path / "campaign_cache")
    dse_cache.configure(d)
    try:
        yield d
    finally:
        dse_cache.configure(None)


# ---------------------------------------------------------------------------
# the JSON artifact store
# ---------------------------------------------------------------------------
def test_store_roundtrip_and_cross_instance_visibility(tmp_path):
    p = str(tmp_path / "store.json")
    a = DseCache(p)
    assert a.get("k") is None
    a.put("k", {"x": 1})
    assert a.get("k") == {"x": 1}
    # a second instance (= another process) sees the flushed value
    b = DseCache(p)
    assert b.get("k") == {"x": 1}
    # writes merge: b adds a key, a picks it up via the mtime check
    b.put("k2", [1, 2, 3])
    assert a.get("k2") == [1, 2, 3]
    assert a.get("k") == {"x": 1}


def test_store_survives_corrupt_file(tmp_path):
    p = str(tmp_path / "store.json")
    a = DseCache(p)
    a.put("k", 7)
    with open(p, "w") as fh:
        fh.write('{"version": 1, "entr')      # torn write
    b = DseCache(p)
    assert b.get("k") is None                  # corrupt -> miss, no raise
    b.put("k2", 8)                             # and it heals on next put
    assert DseCache(p).get("k2") == 8


def test_store_version_mismatch_is_a_miss(tmp_path):
    p = str(tmp_path / "store.json")
    with open(p, "w") as fh:
        json.dump({"version": 0, "entries": {"k": 1}}, fh)
    assert DseCache(p).get("k") is None


# ---------------------------------------------------------------------------
# keys + artifacts
# ---------------------------------------------------------------------------
def test_sim_signature_stable_and_structure_sensitive():
    sim1, _ = build(n_cores=2, n_reqs=6, donate=False)
    sim1b, _ = build(n_cores=2, n_reqs=6, donate=False)
    sim2, _ = build(n_cores=3, n_reqs=6, donate=False)
    assert dse_cache.sim_signature(sim1) == dse_cache.sim_signature(sim1b)
    assert dse_cache.sim_signature(sim1) != dse_cache.sim_signature(sim2)
    # memoized per object: repeated calls are cheap and identical
    assert dse_cache.sim_signature(sim1) == dse_cache.sim_signature(sim1)


def test_artifacts_noop_without_cache_dir():
    assert not dse_cache.active()
    sim, _ = build(n_cores=2, n_reqs=6, donate=False)
    assert dse_cache.get_tuned_top(sim, 1) is None
    dse_cache.put_tuned_top(sim, 1, 32)        # silently dropped
    assert dse_cache.get_tuned_top(sim, 1) is None


def test_tuned_top_keyed_on_sim_and_topology(cache_dir):
    sim1, _ = build(n_cores=2, n_reqs=6, donate=False)
    sim2, _ = build(n_cores=3, n_reqs=6, donate=False)
    dse_cache.put_tuned_top(sim1, 1, 32)
    dse_cache.put_tuned_top(sim1, 2, 64)
    assert dse_cache.get_tuned_top(sim1, 1) == 32
    assert dse_cache.get_tuned_top(sim1, 2) == 64   # per shard topology
    assert dse_cache.get_tuned_top(sim2, 1) is None  # per structure


def test_rung_set_union_merges(cache_dir):
    sim, _ = build(n_cores=2, n_reqs=6, donate=False)
    dse_cache.put_rung_set(sim, 64, 1, {64, 32})
    dse_cache.put_rung_set(sim, 64, 1, {32, 8})
    assert dse_cache.get_rung_set(sim, 64, 1) == [8, 32, 64]
    assert dse_cache.get_rung_set(sim, 64, 2) is None    # topology-keyed
    assert dse_cache.get_rung_set(sim, 128, 1) is None   # B-keyed


def test_family_shape_elementwise_max_merge(cache_dir):
    def bf(**kw):
        pass
    k = dse_cache.family_build_key(bf, (), {"pattern": "mixed"})
    k2 = dse_cache.family_build_key(bf, (), {"pattern": "stream"})
    assert k != k2                             # kwargs are part of the key
    dse_cache.put_family_shape(k, {"core": 2, "l1": 4})
    dse_cache.put_family_shape(k, {"core": 8, "l1": 1})
    assert dse_cache.get_family_shape(k) == {"core": 8, "l1": 4}
    assert dse_cache.get_family_shape(k2) is None


def test_cache_events_and_hit_rate_gauge(cache_dir):
    sim, _ = build(n_cores=2, n_reqs=6, donate=False)
    with capture() as sink:
        dse_cache.get_tuned_top(sim, 1)            # miss
        dse_cache.put_tuned_top(sim, 1, 16)        # write
        dse_cache.get_tuned_top(sim, 1)            # hit
    kinds = [e["kind"] for e in sink.events]
    assert kinds == ["cache.miss", "cache.write", "cache.hit"]
    hit = sink.events[-1]
    assert hit["what"] == "tuned_top" and hit["bytes"] > 0
    w = sink.events[1]
    assert w["bytes"] > 0
    from repro.obs.bus import BUS
    g = BUS.metrics.gauge("dse.cache.hit_rate").value
    assert 0.0 < g <= 1.0


# ---------------------------------------------------------------------------
# the headline: process 2 compiles nothing
# ---------------------------------------------------------------------------
WORKER = textwrap.dedent("""
    import json, os, sys
    import numpy as np
    # count *persistent-cache* hits/misses: a miss is an actual XLA
    # compile; backend_compile events fire even on cache hits, so
    # misses==0 is the real zero-compile assertion
    from jax._src import monitoring
    C = {"hits": 0, "misses": 0}
    def _l(event, **kw):
        if event == "/jax/compilation_cache/cache_hits":
            C["hits"] += 1
        elif event == "/jax/compilation_cache/cache_misses":
            C["misses"] += 1
    monitoring.register_event_listener(lambda e, **kw: _l(e))
    from repro.dse import SweepSpec, run_sweep, cache as dse_cache
    from repro.sims.memsys import build
    assert dse_cache.active(), "REPRO_CACHE_DIR not picked up"
    spec = SweepSpec.grid({"kind.core.think_scale": [1.0, 1.3, 1.6]})
    rows = run_sweep(build, spec, until=2000.0)
    tuned = dse_cache.stats()
    print(json.dumps({"rows": [r["virtual_time"] for r in rows],
                      **C, "artifacts": tuned}))
""")


@pytest.mark.slow
def test_second_process_performs_zero_compiles(tmp_path):
    """Two fresh processes share a campaign cache dir; the second must
    resolve *every* executable from the persistent compilation cache
    (zero cache misses == zero XLA compiles) and produce identical rows
    — plus hit the artifact store where the first populated it."""
    root = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["REPRO_CACHE_DIR"] = str(tmp_path / "shared_cache")

    def run():
        r = subprocess.run([sys.executable, "-c", WORKER],
                           capture_output=True, text=True, timeout=900,
                           env=env)
        assert r.returncode == 0, r.stderr[-4000:]
        return json.loads(r.stdout.strip().splitlines()[-1])

    first, second = run(), run()
    assert second["rows"] == first["rows"]          # caching is invisible
    assert first["misses"] > 0                      # p1 actually compiled
    assert second["misses"] == 0, second            # p2 compiled NOTHING
    # p2 resolves programs from p1's caches: the big rung executables
    # rehydrate whole (artifact `exec` hits, never reaching XLA), the
    # rest (build ops, liveness) deserialize from the persistent
    # compilation cache
    assert second["hits"] > 0
    assert first["artifacts"]["writes"] > 0
    assert second["artifacts"]["hits"] > 0


# ---------------------------------------------------------------------------
# size-capped LRU GC
# ---------------------------------------------------------------------------
def _fake_blob(d, name, nbytes, age_s):
    import time
    p = os.path.join(d, name)
    with open(p, "wb") as fh:
        fh.write(b"x" * nbytes)
    t = time.time() - age_s
    os.utime(p, (t, t))
    return p


def test_gc_evicts_lru_down_to_cap_and_spares_store(cache_dir):
    os.makedirs(cache_dir, exist_ok=True)
    for i in range(5):                    # oldest first: ages 50..10
        _fake_blob(cache_dir, f"exec_{i:04x}.bin", 1000, age_s=50 - 10 * i)
    store_p = os.path.join(cache_dir, dse_cache.STORE_NAME)
    with open(store_p, "w") as fh:        # big store: still never evicted
        fh.write("{}" + " " * 4000)
    before = dse_cache.stats()["evictions"]
    with capture() as sink:
        n = dse_cache.gc(limit=3000)
    assert n == 2                          # two oldest blobs freed 2000B
    left = sorted(os.listdir(cache_dir))
    assert dse_cache.STORE_NAME in left
    assert "exec_0000.bin" not in left and "exec_0001.bin" not in left
    assert "exec_0004.bin" in left
    assert dse_cache.stats()["evictions"] == before + 2
    ev = [e for e in sink.events if e["kind"] == "cache.evict"]
    assert len(ev) == 2 and all(e["bytes"] == 1000 for e in ev)


def test_gc_noop_under_cap_or_unconfigured(cache_dir):
    os.makedirs(cache_dir, exist_ok=True)
    _fake_blob(cache_dir, "exec_aaaa.bin", 100, age_s=10)
    assert dse_cache.gc(limit=10_000) == 0          # under cap
    assert dse_cache.gc() == 0                      # no cap configured
    dse_cache.configure(None)
    assert dse_cache.gc(limit=1) == 0               # no cache dir


def test_configure_max_bytes_and_env_fallback(tmp_path, monkeypatch):
    d = str(tmp_path / "c")
    dse_cache.configure(d, max_bytes=123)
    try:
        assert dse_cache.max_cache_bytes() == 123
        dse_cache.configure(d)                      # reset -> env fallback
        monkeypatch.setenv(dse_cache.ENV_MAX_BYTES, "456")
        assert dse_cache.max_cache_bytes() == 456
        monkeypatch.setenv(dse_cache.ENV_MAX_BYTES, "junk")
        assert dse_cache.max_cache_bytes() is None
        monkeypatch.delenv(dse_cache.ENV_MAX_BYTES)
        assert dse_cache.max_cache_bytes() is None
    finally:
        dse_cache.configure(None)


def test_put_executable_triggers_gc(cache_dir, monkeypatch):
    """Writes keep the dir under the cap automatically: after an
    oversized put, older blobs are gone."""
    os.makedirs(cache_dir, exist_ok=True)
    _fake_blob(cache_dir, "exec_old0.bin", 2000, age_s=100)
    _fake_blob(cache_dir, "exec_old1.bin", 2000, age_s=50)
    dse_cache.configure(cache_dir, max_bytes=4500)
    sim, st = build(n_cores=2, n_reqs=6, donate=False)
    # a real AOT executable write (size ~O(10KB)) blows the cap; both
    # old blobs must age out while the fresh write survives
    import jax, jax.numpy as jnp
    compiled = jax.jit(lambda x: x + 1).lower(jnp.zeros(4)).compile()
    dse_cache.put_executable(sim, 4, 1, compiled)
    left = sorted(os.listdir(cache_dir))
    assert "exec_old0.bin" not in left and "exec_old1.bin" not in left
    assert any(f.startswith("exec_") and f.endswith(".bin") for f in left)
