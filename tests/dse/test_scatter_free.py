"""The hot loop must stay scatter-free with params traced and batched.

On CPU XLA a scatter costs ~two orders of magnitude more than the
equivalent take/select (ENGINE_PERF.md); the engine's delivery/tick phases
are formulated to avoid them, and SimParams enter as broadcast operands
only.  Asserted on the *optimized* HLO (where XLA has already rewritten
constant-index ``.at[].set`` updates into dynamic-update-slices): neither
the batched epoch nor the full batched while-loop run may contain a
scatter op.  Topology-family activity masks (``inst_mask``/``conn_mask``)
must preserve the property: masks enter as broadcast ``&``/``where``
operands only, never as gather/scatter indices.
"""
import re

import jax
import jax.numpy as jnp
import pytest

from repro.dse import build_param_batch, stack_params, stack_state_list, \
    stack_states
from repro.sims import onira
from repro.sims.memsys import build, build_family

B = 4


def _scatters(hlo_text: str) -> list[str]:
    return [ln.strip()[:120] for ln in hlo_text.splitlines()
            if re.search(r"\bscatter\(", ln)]


def _batched(sim, st, points):
    sb = stack_states(st, B)
    pb = build_param_batch(sim, points)
    return sb, pb


def _memsys_batch():
    sim, st = build(n_cores=4, pattern="mixed", n_reqs=8, donate=False)
    points = [{"conn_latency[-1]": 10.0 * (i + 1),
               "kind.l1.extra_hit_rate": 0.2 * i} for i in range(B)]
    return sim, *_batched(sim, st, points)


def test_batched_epoch_hlo_is_scatter_free():
    sim, sb, pb = _memsys_batch()
    fn = jax.jit(jax.vmap(sim._epoch))
    hlo = fn.lower(sb, pb).compile().as_text()
    assert not _scatters(hlo), _scatters(hlo)


def test_batched_full_run_hlo_is_scatter_free():
    sim, sb, pb = _memsys_batch()
    fn = jax.jit(jax.vmap(
        lambda s, p: sim._run(s, 1000.0, 100000, params=p)))
    hlo = fn.lower(sb, pb).compile().as_text()
    assert not _scatters(hlo), _scatters(hlo)


def test_per_lane_horizon_full_run_hlo_is_scatter_free():
    # the straggler-free round loop vmaps until/max_epochs per lane; the
    # per-lane liveness must act through the loop condition and carry
    # selects only — never turn into scatters
    sim, sb, pb = _memsys_batch()
    u = jnp.asarray([100.0, 200.0, 400.0, 800.0], jnp.float32)
    m = jnp.asarray([100, 1000, 10000, 100000], jnp.int32)
    fn = jax.jit(jax.vmap(
        lambda s, p, u, m: sim._run(s, u, m, params=p)))
    hlo = fn.lower(sb, pb, u, m).compile().as_text()
    assert not _scatters(hlo), _scatters(hlo)


def _family_batch():
    """A masked shape batch: lanes are different sub-shapes of one family
    (the structural-DSE hot path)."""
    fam = build_family(n_cores=4, pattern="mixed", n_reqs=8, donate=False)
    shapes = [{"core": s} for s in (1, 2, 3, 4)]
    pb = stack_params([fam.params_for(s) for s in shapes])
    sb = stack_state_list([fam.state_for(s) for s in shapes])
    return fam.sim, sb, pb


def test_masked_batched_epoch_hlo_is_scatter_free():
    sim, sb, pb = _family_batch()
    fn = jax.jit(jax.vmap(sim._epoch))
    hlo = fn.lower(sb, pb).compile().as_text()
    assert not _scatters(hlo), _scatters(hlo)


def test_masked_batched_full_run_hlo_is_scatter_free():
    sim, sb, pb = _family_batch()
    fn = jax.jit(jax.vmap(
        lambda s, p: sim._run(s, 1000.0, 100000, params=p)))
    hlo = fn.lower(sb, pb).compile().as_text()
    assert not _scatters(hlo), _scatters(hlo)


def test_masked_per_lane_horizon_hlo_is_scatter_free():
    # family activity masks + per-lane horizons compose (mixed sub-shapes
    # at mixed horizons is the structural-DSE round-loop hot path)
    sim, sb, pb = _family_batch()
    u = jnp.asarray([100.0, 200.0, 400.0, 800.0], jnp.float32)
    m = jnp.asarray([100, 1000, 10000, 100000], jnp.int32)
    fn = jax.jit(jax.vmap(
        lambda s, p, u, m: sim._run(s, u, m, params=p)))
    hlo = fn.lower(sb, pb, u, m).compile().as_text()
    assert not _scatters(hlo), _scatters(hlo)


def test_batched_onira_epoch_hlo_is_scatter_free():
    # onira's register-scoreboard updates use dynamic indices (oh_set);
    # they too must never compile to scatters under the config vmap
    progs = [onira.prog_br_loop(), onira.prog_raw_hzd()]
    sim, st = onira.build_onira(progs, mem_latency=5.0)
    points = [{"conn_latency": float(i + 1),
               "kind.cpu.flush_cycles": 3.0 + i} for i in range(B)]
    sb, pb = _batched(sim, st, points)
    fn = jax.jit(jax.vmap(sim._epoch))
    hlo = fn.lower(sb, pb).compile().as_text()
    assert not _scatters(hlo), _scatters(hlo)
