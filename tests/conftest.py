import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess dry-run/PDES tests (multi-minute)")
