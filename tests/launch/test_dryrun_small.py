"""Subprocess dry-run tests with 8 fake devices: lowering+compiling a tiny
config on (2,2)/(2,2,2) meshes, plus the sharded-PDES engine on 8 shards.
Subprocesses are required because device count is locked at first jax use.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_train_step_lowers_on_test_meshes():
    r = _run("""
        import jax, dataclasses
        from repro.configs import SHAPES, get_smoke_config
        from repro.launch.mesh import make_test_mesh
        from repro.train.step import TrainHParams, assemble_train
        from repro.parallel.sharding import (activation_sharding,
                                             make_rules_for_mesh)
        cfg = dataclasses.replace(get_smoke_config("stablelm-1.6b"),
                                  d_model=64, n_heads=4, n_kv_heads=4,
                                  head_dim=16, d_ff=128)
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                    global_batch=8)
        for mp in (False, True):
            mesh = make_test_mesh(multi_pod=mp)
            jitted, args = assemble_train(cfg, mesh, shape, TrainHParams())
            with mesh, activation_sharding(mesh,
                                           make_rules_for_mesh(cfg, mesh)):
                compiled = jitted.lower(*args).compile()
            mem = compiled.memory_analysis()
            assert mem.temp_size_in_bytes > 0
            print("OK", mp, mem.temp_size_in_bytes)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.count("OK") == 2


@pytest.mark.slow
def test_decode_step_lowers_on_test_mesh():
    r = _run("""
        import jax, dataclasses
        from repro.configs import SHAPES, get_smoke_config
        from repro.launch.mesh import make_test_mesh
        from repro.serve.step import assemble_decode
        cfg = dataclasses.replace(get_smoke_config("deepseek-67b"),
                                  d_model=64, n_heads=4, n_kv_heads=2,
                                  head_dim=32, d_ff=128)
        shape = dataclasses.replace(SHAPES["decode_32k"], seq_len=128,
                                    global_batch=8)
        mesh = make_test_mesh()
        jitted, args = assemble_decode(cfg, mesh, shape)
        with mesh:
            compiled = jitted.lower(*args).compile()
        print("OK")
    """)
    assert r.returncode == 0, r.stderr[-3000:]


@pytest.mark.slow
def test_pdes_runs_on_8_shards():
    """The sharded conservative-PDES engine actually RUNS (not just lowers)
    on 8 fake devices, and cross-shard writes arrive at neighbor DRAMs."""
    r = _run("""
        import jax
        import numpy as np
        from repro.launch.mesh import make_sim_mesh
        from repro.sims.memsys import build_sharded_memsys
        n = len(jax.devices())
        assert n == 8
        mesh = make_sim_mesh(n)
        ss = build_sharded_memsys(mesh=mesh, n_shards=n, tiles_per_shard=2,
                                  n_reqs=8)
        st = ss.shard_state(ss.init_state())
        out = ss.run(st, until=3000.0)
        served = np.asarray(out.comp_state["dram"]["served"])
        writers = np.asarray(out.comp_state["writer"]["remaining"])
        assert writers.sum() == 0, writers     # all remote writes issued
        # local reads (2 cores x 8 each may hit caches) + remote writes: the
        # DRAM on every shard must have served its neighbor's 8 writes.
        assert (served.reshape(n, -1).sum(axis=1) >= 8).all(), served
        print("OK", served.tolist())
    """)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])


@pytest.mark.slow
def test_pdes_matches_single_shard_semantics():
    """1-shard PDES == plain engine on the same local topology (gateway
    traffic aside): message conservation check."""
    r = _run("""
        import jax
        import numpy as np
        from repro.sims.memsys import build_sharded_memsys
        ss = build_sharded_memsys(n_shards=1, tiles_per_shard=2, n_reqs=8)
        st = ss.init_state()
        out = ss.run(st, until=3000.0)
        core = out.comp_state["core"]
        assert np.asarray(core["remaining"]).sum() == 0
        assert np.asarray(core["outstanding"]).sum() == 0
        print("OK")
    """)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
