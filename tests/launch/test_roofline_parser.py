"""Roofline HLO parser unit tests on synthetic HLO text: loop-trip
multipliers, collective ring factors, dot FLOPs, aliased-op exclusion."""
from repro.launch.roofline import parse_collectives, parse_hlo

HLO = """\
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %lim = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %lim), direction=LT
}

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%y), replica_groups=[4,4]<=[16], to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %p0)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[32,16]{1,0} all-gather(%p0), replica_groups=[4,4]<=[16], dimensions={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_collectives_with_loop_multiplier_and_ring_factor():
    st = parse_collectives(HLO, 16)
    # all-reduce inside 10-trip loop: 2 * 8*16*4 B * (4-1)/4 * 10
    ar = 2 * 8 * 16 * 4 * 0.75 * 10
    # all-gather outside: result 32*16*4 B * 3/4
    ag = 32 * 16 * 4 * 0.75
    assert abs(st.bytes_by_op["all-reduce"] - ar) < 1e-6
    assert abs(st.bytes_by_op["all-gather"] - ag) < 1e-6


def test_dot_flops_with_loop_multiplier():
    st = parse_hlo(HLO, 16)
    # dot: 2 * (8*16 out) * K=16 * 10 trips
    assert abs(st.dot_flops - 2 * 8 * 16 * 16 * 10) < 1e-6


def test_aliased_ops_excluded_from_bytes():
    st = parse_hlo(HLO, 16)
    # gte/tuple/parameter/constant contribute nothing; counted inside loop:
    # dot result + all-reduce result, each 8*16*4 B * 10 trips; outside:
    # the all-gather result 32*16*4 and the s32 adds (4 B * 10).
    expect = (8 * 16 * 4) * 2 * 10 + 32 * 16 * 4 + 4 * 10
    assert abs(st.result_bytes - expect) < 1e-6
