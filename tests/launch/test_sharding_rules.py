"""Every (assigned arch × production mesh) must yield divisible parameter
shardings — the static guarantee behind the dry-run's zero sharding errors.
Runs meshless: validates PSpec dims against the mesh axis sizes directly."""
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as tfm
from repro.models.layers import PSpec
from repro.parallel.sharding import make_rules

MESHES = {
    "16x16": {"data": 16, "model": 16, "_dp": ("data",)},
    "2x16x16": {"pod": 2, "data": 16, "model": 16, "_dp": ("pod", "data")},
}


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_name", list(MESHES))
def test_param_shardings_divide(arch, mesh_name):
    import jax
    m = MESHES[mesh_name]
    cfg = get_config(arch)
    rules = make_rules(cfg, m["model"], m["_dp"])
    specs = tfm.model_specs(cfg)

    bad = []

    def check(path, ps):
        for dim, ax in zip(ps.shape, ps.axes):
            phys = rules.get(ax) if ax is not None else None
            if phys is None:
                continue
            names = (phys,) if isinstance(phys, str) else phys
            n = 1
            for nm in names:
                n *= m[nm]
            if dim % n != 0:
                bad.append((path, ps.shape, ax, n))

    def walk(tree, path=""):
        if isinstance(tree, PSpec):
            check(path, tree)
        elif isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, f"{path}/{k}")

    walk(specs)
    assert not bad, bad


def test_kv_fallbacks_active_where_needed():
    # kv=8 archs cannot shard kv heads over 16 — the rule must fall back
    for arch in ("deepseek-67b", "grok-1-314b", "internvl2-26b"):
        cfg = get_config(arch)
        rules = make_rules(cfg, 16, ("data",))
        assert rules["tensor_kv"] is None
    # phi3's 40 q-heads don't divide 16 either
    assert make_rules(get_config("phi3-medium-14b"), 16,
                      ("data",))["tensor_q"] is None
    # but stablelm (32 heads) shards fine
    assert make_rules(get_config("stablelm-1.6b"), 16,
                      ("data",))["tensor_q"] == "model"


def test_moe_mode_selection():
    assert make_rules(get_config("deepseek-v2-236b"), 16,
                      ("data",))["expert"] == "model"      # EP: 160/16
    g = make_rules(get_config("grok-1-314b"), 16, ("data",))
    assert g["expert"] is None and g["expert_ff"] == "model"  # TP fallback
