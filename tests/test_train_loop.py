"""Integration: tiny model trains (loss decreases); kill/resume is
bit-exact vs the uninterrupted run; serve prefill+decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataPipeline
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainHParams


def _tiny_cfg():
    return dataclasses.replace(get_smoke_config("stablelm-1.6b"),
                               n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=2, head_dim=16, d_ff=64, vocab=64)


def _data(cfg):
    return DataPipeline(cfg, batch=4, seq=16, seed=0)


def test_loss_decreases(tmp_path):
    cfg = _tiny_cfg()
    _, _, hist = train(cfg, _data(cfg),
                       LoopConfig(steps=30, ckpt_every=100,
                                  ckpt_dir=str(tmp_path), log_every=1000),
                       TrainHParams(lr=1e-2, donate=False))
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_kill_resume_bit_exact(tmp_path):
    cfg = _tiny_cfg()
    hp = TrainHParams(lr=1e-2, donate=False)
    # uninterrupted 20-step run
    pa, _, _ = train(cfg, _data(cfg),
                     LoopConfig(steps=20, ckpt_every=100,
                                ckpt_dir=str(tmp_path / "a"),
                                log_every=1000), hp)
    # interrupted: 10 steps (checkpoint at 10), then resume to 20
    train(cfg, _data(cfg),
          LoopConfig(steps=10, ckpt_every=10, ckpt_dir=str(tmp_path / "b"),
                     log_every=1000), hp)
    pb, _, _ = train(cfg, _data(cfg),
                     LoopConfig(steps=20, ckpt_every=100,
                                ckpt_dir=str(tmp_path / "b"),
                                log_every=1000), hp, resume=True)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_grad_accumulation_matches_full_batch():
    cfg = _tiny_cfg()
    from repro.train.step import make_train_step
    from repro.optim import adamw_init
    params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in _data(cfg)(0).items()}
    outs = []
    for mb in (1, 2, 4):
        opt = adamw_init(params)
        step = jax.jit(make_train_step(
            cfg, TrainHParams(lr=1e-2, micro_batches=mb, donate=False)))
        loss, gnorm, p2, _ = step(params, opt, batch)
        outs.append((float(loss), float(gnorm)))
    for l, g in outs[1:]:
        assert abs(l - outs[0][0]) < 2e-2
        assert abs(g - outs[0][1]) / outs[0][1] < 0.05


def test_prefill_decode_matches_full_forward():
    """Greedy continuation via prefill+decode == recomputing full forward."""
    cfg = _tiny_cfg()
    params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(1))
    B, S0, T = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S0), 0, cfg.vocab)

    # reference: grow the sequence, full forward each time
    ref_seq = toks
    for _ in range(T):
        logits, _, _ = tfm.forward(params, cfg, {"tokens": ref_seq},
                                   mode="train")
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        ref_seq = jnp.concatenate([ref_seq, nxt], axis=1)

    # cached: prefill then decode steps
    logits, pcache, _ = tfm.forward(params, cfg, {"tokens": toks},
                                    mode="prefill")
    cache = tfm.init_cache(cfg, B, S0 + T)
    cache = {k: (v.at[:, :, :S0].set(pcache[k].astype(v.dtype))
                 if k in ("k", "v", "ckv", "kr") else
                 pcache[k].astype(v.dtype))
             for k, v in cache.items()}
    seq = toks
    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    seq = jnp.concatenate([seq, nxt], axis=1)
    for t in range(S0, S0 + T - 1):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache, _ = tfm.forward(params, cfg, {"tokens": nxt},
                                       mode="decode", cache=cache,
                                       positions=pos, cache_len=pos + 1)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        seq = jnp.concatenate([seq, nxt], axis=1)
    np.testing.assert_array_equal(np.asarray(seq), np.asarray(ref_seq))
