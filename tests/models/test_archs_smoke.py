"""Per-architecture smoke tests: reduced config, one train step + (for
causal archs) a prefill+decode step on CPU — shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import transformer as tfm
from repro.models.layers import init_params


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.frontend == "audio":
        batch["features"] = jax.random.normal(ks[0], (B, S, cfg.frontend_dim),
                                              jnp.float32)
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
        batch["mask"] = (jax.random.uniform(ks[2], (B, S)) < 0.3).astype(
            jnp.float32)
    elif cfg.frontend == "vision":
        nv = cfg.n_vision_tokens
        batch["tokens"] = jax.random.randint(ks[0], (B, S - nv), 0, cfg.vocab)
        batch["vision"] = jax.random.normal(ks[1], (B, nv, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(tfm.model_specs(cfg), key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: tfm.train_loss(p, cfg, batch)))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert-xlarge"])
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(tfm.model_specs(cfg), key)
    B, S_ctx, S_max = 2, 8, 12
    if cfg.frontend == "vision":
        batch = _batch(cfg, jax.random.PRNGKey(1), B=B, S=S_ctx)
    else:
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (B, S_ctx), 0, cfg.vocab)}

    if tfm.needs_unrolled_decode(cfg, S_max):
        # heterogeneous cache: prefill via teacher-forced decode steps
        cache = tfm.init_cache_unrolled(cfg, B, S_max)
        toks = batch["tokens"] if "tokens" in batch else None
        logits = None
        for t in range(S_ctx):
            pos = jnp.full((B, 1), t, jnp.int32)
            logits, cache = tfm.decode_unrolled(
                params, cfg, toks[:, t:t + 1], cache, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        for t in range(S_ctx, S_max):
            pos = jnp.full((B, 1), t, jnp.int32)
            logits, cache = tfm.decode_unrolled(
                params, cfg, nxt[:, None], cache, pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1)
            assert np.all(np.isfinite(np.asarray(logits))), arch
        return

    logits, pcache, _ = tfm.forward(params, cfg, batch, mode="prefill")
    assert np.all(np.isfinite(np.asarray(logits[:, -1])))
    # place prefill cache into the padded decode cache
    cache = tfm.init_cache(cfg, B, S_max)
    S_pref = S_ctx if cfg.frontend != "vision" else S_ctx  # total seq
    def put(dst, src):
        if src.ndim >= 3 and dst.shape[2] >= src.shape[1] and \
                dst.shape[1] == src.shape[0]:
            pass
        return dst
    merged = {}
    for k_, dst in cache.items():
        src = pcache[k_]
        if k_ in ("k", "v", "ckv", "kr"):
            merged[k_] = dst.at[:, :, :src.shape[2]].set(src.astype(dst.dtype))
        else:
            merged[k_] = src.astype(dst.dtype)
    cache = merged
    nxt = jnp.argmax(logits[:, -1], axis=-1)
    for t in range(S_pref, S_max):
        pos = jnp.full((B, 1), t, jnp.int32)
        dbatch = {"tokens": nxt[:, None]}
        logits, cache, _ = tfm.forward(params, cfg, dbatch, mode="decode",
                                       cache=cache, positions=pos,
                                       cache_len=pos + 1)
        assert np.all(np.isfinite(np.asarray(logits))), arch
        nxt = jnp.argmax(logits[:, -1], axis=-1)


def test_param_counts_match_analytic():
    # init_params materializes exactly param_count() parameters (tied embeds
    # counted once; vocab padding excluded from the analytic count).
    for arch in ["stablelm-1.6b", "mamba2-130m", "grok-1-314b"]:
        cfg = get_smoke_config(arch)
        params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(0))
        n = sum(x.size for x in jax.tree.leaves(params))
        pad = (cfg.vocab_padded - cfg.vocab) * cfg.d_model
        n -= pad * (1 if cfg.tie_embeddings else 2)
        expect = cfg.param_count()
        assert abs(n - expect) / expect < 0.02, \
            f"{arch}: {n} vs analytic {expect}"
