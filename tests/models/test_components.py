"""Component-level equivalence tests: MoE dispatch vs dense oracle,
group-wise vs monolithic dispatch, MLA absorbed-decode vs materialized,
SSM decode-from-prefill continuation, RoPE properties (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                           # optional: only the property test needs it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import rope
from repro.models.layers import init_params


def _moe_cfg(**kw):
    cfg = get_smoke_config("grok-1-314b")
    return dataclasses.replace(cfg, **kw)


def test_moe_matches_dense_ref_when_no_drops():
    cfg = _moe_cfg(moe_capacity=8.0)        # ample capacity: no drops
    params = init_params(moe_mod.moe_specs(cfg), jax.random.PRNGKey(0),
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    out, aux = moe_mod.moe_block(params, cfg, x)
    ref = moe_mod.moe_block_dense_ref(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-3)
    assert float(aux) > 0


def test_moe_groupwise_matches_monolithic():
    cfg1 = _moe_cfg(moe_capacity=8.0, moe_groups=1)
    cfg4 = _moe_cfg(moe_capacity=8.0, moe_groups=4)
    params = init_params(moe_mod.moe_specs(cfg1), jax.random.PRNGKey(0),
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg1.d_model),
                          jnp.float32) * 0.5
    o1, _ = moe_mod.moe_block(params, cfg1, x)
    o4, _ = moe_mod.moe_block(params, cfg4, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4),
                               atol=2e-4, rtol=2e-3)


def test_moe_capacity_drops_are_bounded():
    cfg = _moe_cfg(moe_capacity=0.5)        # force drops
    params = init_params(moe_mod.moe_specs(cfg), jax.random.PRNGKey(0),
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    out, _ = moe_mod.moe_block(params, cfg, x)
    assert np.all(np.isfinite(np.asarray(out)))


def test_mla_absorbed_decode_matches_materialized():
    """Decode (absorbed, latent cache) must equal the train-form attention
    restricted to the causal prefix, position by position."""
    from repro.models import mla as mla_mod
    cfg = get_smoke_config("deepseek-v2-236b")
    params = init_params(mla_mod.mla_specs(cfg), jax.random.PRNGKey(0),
                         dtype=jnp.float32)
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    full, _ = mla_mod.mla_block(params, cfg, x, pos)          # train form

    cache = (jnp.zeros((B, S, cfg.kv_lora), jnp.float32),
             jnp.zeros((B, S, cfg.qk_rope_dim), jnp.float32))
    outs = []
    for t in range(S):
        pt = jnp.full((B, 1), t, jnp.int32)
        o, cache = mla_mod.mla_block(params, cfg, x[:, t:t + 1], pt,
                                     cache=cache, cache_len=pt + 1)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=3e-4, rtol=3e-3)


def test_ssm_decode_continues_prefill():
    cfg = get_smoke_config("mamba2-130m")
    params = init_params(ssm_mod.ssm_specs(cfg), jax.random.PRNGKey(0),
                         dtype=jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model),
                          jnp.float32) * 0.5
    full, _ = ssm_mod.ssm_block(params, cfg, x)               # all S+1
    _, cache = ssm_mod.ssm_block(params, cfg, x[:, :S], cache="init")
    step, _ = ssm_mod.ssm_block(params, cfg, x[:, S:S + 1], cache=cache)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full[:, S:]),
                               atol=1e-3, rtol=1e-2)


@pytest.mark.parametrize("arch", ["mamba2-130m", "hymba-1.5b"])
def test_ssd_grads_finite_on_long_repetitive_data(arch):
    """Regression: the SSD decay mask must clamp BEFORE exp — repetitive
    pipeline data at S=64 drove exp(seg) to inf on masked entries and the
    where-gradient produced NaN (inf x 0)."""
    import jax
    from repro.data import DataPipeline
    from repro.models import transformer as tfm
    from repro.models.layers import init_params
    cfg = get_smoke_config(arch)
    params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in DataPipeline(cfg, batch=4, seq=64, seed=0)(0).items()}
    loss, grads = jax.value_and_grad(
        lambda p: tfm.train_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


def _check_rope_is_relative(pos, delta, seed):
    """<rope(q,p), rope(k,p+d)> depends only on d (relative encoding)."""
    hd = 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(k1, (1, 1, 1, hd))
    k = jax.random.normal(k2, (1, 1, 1, hd))

    def score(p):
        qp = rope(q, jnp.full((1, 1), p, jnp.int32), 10_000.0)
        kp = rope(k, jnp.full((1, 1), p + delta, jnp.int32), 10_000.0)
        return float(jnp.sum(qp * kp))

    assert abs(score(pos) - score(0)) < 1e-2
    # norms preserved
    qp = rope(q, jnp.full((1, 1), pos, jnp.int32), 10_000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(qp)),
                               float(jnp.linalg.norm(q)), rtol=1e-5)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(pos=st.integers(0, 512), delta=st.integers(0, 64),
           seed=st.integers(0, 100))
    def test_rope_is_relative(pos, delta, seed):
        _check_rope_is_relative(pos, delta, seed)
else:
    def test_rope_is_relative():
        _check_rope_is_relative(317, 41, 7)
        pytest.importorskip("hypothesis")
