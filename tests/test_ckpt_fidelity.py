"""Round-trip fidelity of `repro.ckpt` (property-style, seeded).

Rung checkpoints of warm searches flow whole `SimState` trees through
`save_checkpoint` / `restore_checkpoint`, so the round trip must be
*exact* for every leaf kind the engine uses: bool masks, integer clocks
(including 64-bit counters with x64 disabled — `jnp.asarray` before the
dtype fixup used to silently truncate them), weakly-typed scalars,
floats and empty arrays.  The tests run a seeded dtype x shape grid and
randomly composed nested trees instead of `hypothesis` (which the
container does not ship); the generators are deterministic per seed.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import restore_checkpoint, save_checkpoint
from repro.sims.memsys import build

DTYPES = [np.bool_, np.int8, np.int16, np.int32, np.int64, np.uint8,
          np.uint16, np.uint32, np.uint64, np.float16, np.float32,
          np.float64, np.complex64]
SHAPES = [(), (1,), (5,), (2, 3), (2, 0), (1, 2, 3)]


def _rand(rng, dt, shape):
    dt = np.dtype(dt)
    if dt == np.bool_:
        return rng.integers(0, 2, size=shape).astype(bool)
    if dt.kind in "iu":
        info = np.iinfo(dt)
        # extreme values included: truncation bugs hide at the edges
        a = rng.integers(info.min, info.max, size=shape, dtype=dt,
                         endpoint=True)
        return a
    if dt.kind == "c":
        return (rng.standard_normal(shape)
                + 1j * rng.standard_normal(shape)).astype(dt)
    if dt == np.float64:
        # values a float32 round-trip would corrupt
        return rng.standard_normal(shape) * (1.0 + 1e-12) + 1e-9
    return rng.standard_normal(shape).astype(dt)


@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: np.dtype(d).name)
def test_roundtrip_exact_per_dtype(tmp_path, dt):
    rng = np.random.default_rng(abs(hash(np.dtype(dt).name)) % 2**32)
    tree = {f"s{i}": _rand(rng, dt, s) for i, s in enumerate(SHAPES)}
    save_checkpoint(str(tmp_path), tree, 0)
    back, _ = restore_checkpoint(str(tmp_path), tree)
    for k, want in tree.items():
        got = np.asarray(back[k])
        assert got.dtype == want.dtype, (k, got.dtype, want.dtype)
        assert got.shape == want.shape, (k, got.shape, want.shape)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", range(5))
def test_roundtrip_exact_random_nested_trees(tmp_path, seed):
    """Property-style: randomly composed nested dict/list/tuple trees of
    random dtype/shape leaves round-trip leaf-for-leaf, bit-for-bit."""
    rng = np.random.default_rng(seed)

    def gen(depth):
        if depth == 0 or rng.random() < 0.4:
            dt = DTYPES[int(rng.integers(len(DTYPES)))]
            shape = SHAPES[int(rng.integers(len(SHAPES)))]
            return _rand(rng, dt, shape)
        kind = rng.random()
        n = int(rng.integers(1, 4))
        if kind < 0.5:
            return {f"k{i}": gen(depth - 1) for i in range(n)}
        if kind < 0.75:
            return [gen(depth - 1) for _ in range(n)]
        return tuple(gen(depth - 1) for _ in range(n))

    tree = {"root": gen(3)}
    save_checkpoint(str(tmp_path), tree, 0)
    back, _ = restore_checkpoint(str(tmp_path), tree)
    la, lb = jax.tree.leaves(tree), jax.tree.leaves(back)
    assert len(la) == len(lb)
    for want, got in zip(la, lb):
        got = np.asarray(got)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_int64_counters_survive_with_x64_disabled(tmp_path):
    """The regression the warm-search rung checkpoints exposed: a
    64-bit leaf restored through `jnp.asarray` with x64 off was
    truncated to 32 bits *before* the dtype fixup — values beyond
    2**31 / float32 precision came back corrupted."""
    assert not jax.config.jax_enable_x64       # the setup this pins
    tree = {"clock": np.asarray([2**40 + 7, -(2**35)], np.int64),
            "t": np.asarray([1.0 + 2**-40], np.float64),
            "u": np.asarray([2**63 - 1], np.uint64)}
    save_checkpoint(str(tmp_path), tree, 0)
    back, _ = restore_checkpoint(str(tmp_path), tree)
    for k, want in tree.items():
        got = np.asarray(back[k])
        assert got.dtype == want.dtype, (k, got.dtype)
        np.testing.assert_array_equal(got, want)


def test_simstate_leaves_roundtrip_bit_exact(tmp_path):
    """A real evolved SimState — bool masks, integer clocks, f32 times,
    weakly-typed scalars — through the exact tree shape the warm-search
    rung checkpoints use ({key: [leaves...]})."""
    sim, st = build(n_cores=3, pattern="mixed", n_reqs=6, donate=False)
    out = sim.run(sim.copy_state(st), 400.0)
    leaves = jax.tree.leaves(out)
    kinds = {np.asarray(x).dtype.kind for x in leaves}
    assert "f" in kinds and "i" in kinds       # the mix that matters
    tree = {"handles": {"0|{}": list(leaves)}}
    save_checkpoint(str(tmp_path), tree, 3)
    back, manifest = restore_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 3
    got = back["handles"]["0|{}"]
    assert len(got) == len(leaves)
    for want, g in zip(leaves, got):
        w = np.asarray(want)
        g = np.asarray(g)
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(g, w)
    # the restored leaves rebuild a usable state: same treedef, and the
    # engine continues it exactly as it continues the original
    treedef = jax.tree.structure(out)
    rebuilt = jax.tree.unflatten(treedef, got)
    a = sim.run(jax.tree.map(jnp.asarray, rebuilt), 800.0)
    b = sim.run(sim.copy_state(out), 800.0)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_nonfinite_and_extreme_floats_roundtrip(tmp_path):
    """Engine states carry +inf next-event times; NaN and denormals must
    also survive (array_equal treats NaN positions as equal here)."""
    tree = {"x": np.asarray([np.inf, -np.inf, np.nan, 0.0, -0.0,
                             np.finfo(np.float32).tiny,
                             math.pi], np.float32)}
    save_checkpoint(str(tmp_path), tree, 0)
    back, _ = restore_checkpoint(str(tmp_path), tree)
    got = np.asarray(back["x"])
    assert got.dtype == np.float32
    np.testing.assert_array_equal(got, tree["x"])
    assert np.signbit(got[4])                  # -0.0 keeps its sign bit
