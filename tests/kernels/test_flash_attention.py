"""Flash-attention kernel: interpret-mode allclose vs the pure-jnp oracle,
swept over shapes, dtypes, GQA group counts, masks, windows, softcaps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fak
from repro.kernels.flash_attention.ref import flash_attention_ref


def _mk(B, Sq, Sk, H, KV, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


CASES = [
    # B, S, H, KV, hd, causal, window, cap
    (1, 128, 2, 2, 32, True, 0, 0.0),
    (2, 256, 4, 2, 16, True, 0, 0.0),       # GQA G=2
    (1, 256, 4, 1, 32, True, 64, 0.0),      # sliding window, G=4
    (2, 128, 2, 2, 64, True, 0, 50.0),      # softcap
    (1, 128, 4, 4, 32, False, 0, 0.0),      # bidirectional (hubert)
    (1, 512, 8, 2, 64, True, 128, 30.0),    # everything at once
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, S, H, KV, hd, causal, window, cap = case
    q, k, v = _mk(B, S, S, H, KV, hd, dtype)
    out = fak.flash_attention(q, k, v, n_kv_heads=KV, causal=causal,
                              window=window, cap=cap, block_q=64, block_k=64,
                              interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_block_shape_independence():
    q, k, v = _mk(1, 256, 256, 2, 2, 32, jnp.float32)
    outs = []
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        outs.append(np.asarray(fak.flash_attention(
            q, k, v, n_kv_heads=2, causal=True, block_q=bq, block_k=bk,
            interpret=True)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)
