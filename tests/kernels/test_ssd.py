"""SSD kernel: interpret-mode allclose vs the sequential-recurrence oracle
(and the chunked jnp form), swept over shapes/dtypes/chunk sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import kernel as ssdk
from repro.kernels.ssd.ref import ssd_chunked, ssd_ref


def _mk(B, S, H, P, N, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xs = jax.random.normal(ks[0], (B, S, H, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(ks[1], (B, S, H), jnp.float32) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, N), jnp.float32).astype(dtype)
    C_ = jax.random.normal(jax.random.PRNGKey(seed + 9), (B, S, N),
                           jnp.float32).astype(dtype)
    return xs, dt, A, B_, C_


CASES = [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),   # mamba2-130m-like head
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_recurrence(B, S, H, P, N, chunk, dtype):
    xs, dt, A, B_, C_ = _mk(B, S, H, P, N, dtype)
    y, hT = ssdk.ssd(xs, dt, A, B_, C_, chunk=chunk, interpret=True)
    y_ref, hT_ref = ssd_ref(xs, dt, A, B_, C_)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               atol=tol, rtol=tol)


def test_chunked_jnp_matches_recurrence():
    xs, dt, A, B_, C_ = _mk(1, 128, 2, 16, 8, jnp.float32)
    y1, h1 = ssd_chunked(xs, dt, A, B_, C_, chunk=16)
    y2, h2 = ssd_ref(xs, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4,
                               rtol=1e-4)


def test_chunk_size_independence():
    xs, dt, A, B_, C_ = _mk(1, 128, 2, 16, 8, jnp.float32, seed=3)
    outs = [np.asarray(ssdk.ssd(xs, dt, A, B_, C_, chunk=c,
                                interpret=True)[0])
            for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-4)
