"""First-party component library tests: write policies, TLB/MMU chain,
banked DRAM row-buffer accounting, and the paper's Fig-6 backtrace panic."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ComponentKind, SimBuilder, TickResult, msg_new, payload
from repro.core.tracing import TracingDomain
from repro.sims.components import (LINE, PAGE, READ_REQ, READ_RESP,
                                   WRITE_ACK, WRITE_REQ, make_cache_kind,
                                   make_dram_kind)
from repro.sims.xlat import PageFault, run_translation_study


def _driver_kind(ops):
    """Issues a scripted list of (op, addr) one at a time, waits for each
    response/ack."""
    ops = np.asarray(ops, np.int32)

    def tick(state, ports, t):
        state = dict(state)
        msg, got, ports = ports.recv(0)
        state["waiting"] = jnp.where(got, 0, state["waiting"])
        state["acks"] = state["acks"] + got.astype(jnp.int32)
        idx = state["idx"]
        want = (state["waiting"] == 0) & (idx < ops.shape[0])
        row = state["ops"][jnp.clip(idx, 0, ops.shape[0] - 1)]
        ports, sent = ports.send(0, msg_new(row[0], p0=row[1], p1=idx),
                                 when=want)
        state["idx"] = state["idx"] + sent.astype(jnp.int32)
        state["waiting"] = jnp.where(sent, 1, state["waiting"])
        return state, ports, TickResult.make(got | sent)

    return ComponentKind("driver", tick, 1, 1, {
        "ops": jnp.asarray(ops)[None, :, :],
        "idx": jnp.zeros(1, jnp.int32),
        "waiting": jnp.zeros(1, jnp.int32),
        "acks": jnp.zeros(1, jnp.int32)}, cap=2)


def _mem_kind():
    def tick(state, ports, t):
        msg, got, ports = ports.recv(0, when=ports.can_send(0))
        is_read = got & (msg[0] == READ_REQ)
        ports, _ = ports.send(
            0, msg_new(READ_RESP, p0=payload(msg, 0), p1=payload(msg, 1)),
            when=is_read)
        state = {"reads": state["reads"] + is_read.astype(jnp.int32),
                 "writes": state["writes"] +
                 (got & (msg[0] == WRITE_REQ)).astype(jnp.int32)}
        return state, ports, TickResult.make(got)

    return ComponentKind("mem", tick, 1, 1,
                         {"reads": jnp.zeros(1, jnp.int32),
                          "writes": jnp.zeros(1, jnp.int32)}, cap=4)


def _run_cache(ops, write_back):
    b = SimBuilder()
    drv = b.add_kind(_driver_kind(ops))
    cache = b.add_kind(make_cache_kind("c", 1, n_sets=16,
                                       write_back=write_back))
    mem = b.add_kind(_mem_kind())
    b.connect([drv.port(0, 0), cache.port(0, 0)], latency=1.0)
    b.connect([cache.port(0, 1), mem.port(0, 0)], latency=4.0)
    sim = b.build()
    out = sim.run(sim.init_state(), until=5000.0)
    return out.comp_state


def test_write_through_forwards_every_write():
    A = 0x100
    ops = [(READ_REQ, A), (WRITE_REQ, A), (WRITE_REQ, A), (READ_REQ, A)]
    cs = _run_cache(ops, write_back=False)
    assert int(cs["driver"]["acks"][0]) == 4
    assert int(cs["mem"]["writes"][0]) == 2          # both writes forwarded
    assert int(cs["c"]["hits"][0]) == 3              # everything after fill


def test_write_back_holds_dirty_lines():
    A = 0x100
    ops = [(READ_REQ, A), (WRITE_REQ, A), (WRITE_REQ, A), (READ_REQ, A)]
    cs = _run_cache(ops, write_back=True)
    assert int(cs["driver"]["acks"][0]) == 4
    assert int(cs["mem"]["writes"][0]) == 0          # dirty, not written out
    assert int(cs["c"]["hits"][0]) == 3


def test_write_back_evicts_dirty_victim():
    A = 0x100
    B_ = A + 16 * LINE                                # same set, new tag
    ops = [(READ_REQ, A), (WRITE_REQ, A), (READ_REQ, B_)]
    cs = _run_cache(ops, write_back=True)
    assert int(cs["driver"]["acks"][0]) == 3
    assert int(cs["mem"]["writes"][0]) == 1          # victim written back


def test_tlb_mmu_chain_counts():
    # two pages, revisited: L1 cold-misses twice then hits
    addrs = [0 * PAGE + 8, 1 * PAGE + 8, 0 * PAGE + 64, 1 * PAGE + 64,
             0 * PAGE + 128]
    stats = run_translation_study(addrs)
    assert stats["translated"] == 5
    assert stats["l1_misses"] == 2 and stats["walks"] == 2
    assert stats["l1_hits"] == 3
    assert stats["l2_misses"] == 2


def test_page_fault_enhanced_backtrace(capsys):
    addrs = [0 * PAGE + 8, (1 << 12) * PAGE]          # second page unmapped
    with pytest.raises(PageFault):
        run_translation_study(addrs, max_vpn=1 << 10)
    out = capsys.readouterr().out
    # the paper's Fig-6b cause chain, root -> leaf
    for frag in ("@Core0, instruction, load", "@L1TLB[0], translation",
                 "@L2TLB, translation", "@MMU, page-walk"):
        assert frag in out, out


def test_dram_row_buffer_hits():
    same_row = [(READ_REQ, 64 * i) for i in range(4)]          # one row
    b = SimBuilder()
    drv = b.add_kind(_driver_kind(same_row))
    dram = b.add_kind(make_dram_kind("dram", 1, n_banks=1, row_bits=11))
    b.connect([drv.port(0, 0), dram.port(0, 0)], latency=2.0)
    sim = b.build()
    out = sim.run(sim.init_state(), until=2000.0)
    cs = out.comp_state
    assert int(cs["dram"]["served"][0]) == 4
    assert int(cs["dram"]["row_hits"][0]) == 3        # first opens the row
