"""Case-study simulators: memsys correctness + smart/naive agreement,
Onira CPI accuracy vs analytic pipeline model, TrioSim vs closed form."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.sims.memsys import build, finish_stats
from repro.sims.onira import (analytic_cpi, run_microbenches, run_mlp_sweep)
from repro.sims.opgraph import analytic_step_us
from repro.sims.triosim import simulate_step


@pytest.mark.parametrize("pattern", ["mixed", "idle_half", "stream"])
def test_memsys_completes_and_matches_naive(pattern):
    sim_s, st_s = build(n_cores=8, pattern=pattern, n_reqs=24)
    out_s = sim_s.run(st_s, until=20000.0)
    s = finish_stats(sim_s, out_s)
    assert s["remaining"] == 0 and s["outstanding"] == 0
    sim_n, st_n = build(n_cores=8, pattern=pattern, n_reqs=24, naive=True)
    out_n = sim_n.run(st_n, until=float(np.ceil(s["virtual_time"])) + 2)
    n = finish_stats(sim_n, out_n)
    for k in ("reads_done", "hits", "misses", "delivered", "remaining"):
        assert s[k] == n[k], (k, s[k], n[k])
    # Smart Ticking must skip most component ticks (the paper's win)
    assert s["ticks"] < 0.2 * n["ticks"]
    assert s["epochs"] < n["epochs"]


def test_memsys_cache_hits_on_sequential_stream():
    sim, st = build(n_cores=4, pattern="stream", n_reqs=64)
    out = sim.run(st, until=50000.0)
    s = finish_stats(sim, out)
    # 64B lines, +64 stride => every line new: all misses is also fine for
    # stride 64; hits come from the LCG pattern reuse — just check counts add
    assert s["hits"] + s["misses"] == 64 * 4


def test_onira_cpi_within_paper_band():
    res = run_microbenches()
    for name, r in res.items():
        assert r["done"], name
        ref = analytic_cpi(name)
        err = abs(r["cpi"] - ref) / ref
        assert err < 0.20, (name, r["cpi"], ref)   # paper: 10-20%


def test_onira_mlp_saturates():
    mlp = run_mlp_sweep(n_values=(1, 4, 16))
    assert mlp[1] > mlp[4] > mlp[16] - 1e-6
    assert mlp[16] < 2.0


@pytest.mark.parametrize("plan", [(2, 1, 1), (1, 2, 1), (1, 1, 2)])
def test_triosim_matches_analytic(plan):
    dp, tp, pp = plan
    cfg = dataclasses.replace(get_config("stablelm-1.6b"), n_layers=8)
    r = simulate_step(cfg, batch=4, seq=512, dp=dp, tp=tp, pp=pp, micro=2)
    a = analytic_step_us(cfg, 4, 512, dp, tp, pp, 2)
    assert r["done"]
    assert 0.9 < r["step_us"] / a < 1.15, (plan, r["step_us"], a)
