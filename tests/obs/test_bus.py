"""The telemetry bus contract: zero-cost when disabled, flat versioned
events, resilient sinks, the metrics registry, JSONL round-trip."""
import json
import threading

import pytest

from repro.obs import (BUS, SCHEMA_VERSION, Bus, JsonlSink, MemorySink,
                       capture, read_jsonl)
from repro.obs.bus import MAX_SINK_ERRORS


def test_disabled_emit_materializes_nothing():
    bus = Bus()
    seq0 = bus.seq
    assert bus.emit("anything", x=1) is None
    assert not bus.active
    assert bus.seq == seq0          # the monotonic counter never moved


def test_emit_fans_out_in_order():
    bus = Bus()
    a, b = MemorySink(), MemorySink()
    bus.attach(a)
    bus.attach(b)
    bus.emit("one", x=1)
    bus.emit("two", y="z")
    assert a.kinds() == ["one", "two"] == b.kinds()
    assert [e["seq"] for e in a.events] == [0, 1]
    assert all("ts" in e for e in a.events)
    assert a.events[0]["x"] == 1 and a.events[1]["y"] == "z"
    bus.detach(a)
    bus.emit("three")
    assert a.kinds() == ["one", "two"]
    assert b.kinds() == ["one", "two", "three"]


def test_reserved_keys_cannot_be_overridden_by_mistake():
    bus = Bus()
    sink = bus.attach(MemorySink())
    ev = bus.emit("k", dur=0.5, what="rows")
    assert ev["kind"] == "k" and ev["dur"] == 0.5
    assert sink.events[-1] is ev


def test_sink_errors_never_propagate():
    bus = Bus()

    class Bad:
        def on_event(self, ev):
            raise RuntimeError("boom")

    good = MemorySink()
    bus.attach(Bad())
    bus.attach(good)
    for _ in range(MAX_SINK_ERRORS + 5):
        bus.emit("k")
    assert len(good.events) == MAX_SINK_ERRORS + 5   # campaign survived
    assert len(bus.sink_errors) == MAX_SINK_ERRORS   # bounded record
    assert bus.sink_errors[0][0] == "Bad"


def test_span_emits_completed_duration():
    bus = Bus()
    sink = bus.attach(MemorySink())
    with bus.span("work", label="x") as extra:
        extra["n"] = 3
    (ev,) = sink.events
    assert ev["kind"] == "work" and ev["label"] == "x" and ev["n"] == 3
    assert ev["dur"] >= 0.0


def test_metrics_registry():
    bus = Bus()
    bus.attach(MemorySink())       # metric sugar is active-gated
    bus.count("c")
    bus.count("c", 2)
    bus.gauge("g", 7.5)
    for v in (1.0, 3.0, 2.0):
        bus.observe("h", v)
    snap = bus.metrics.snapshot()
    assert snap["c"] == 3.0
    assert snap["g"] == 7.5
    assert snap["h"]["count"] == 3 and snap["h"]["min"] == 1.0
    assert snap["h"]["max"] == 3.0 and snap["h"]["last"] == 2.0
    assert snap["h"]["mean"] == pytest.approx(2.0)
    with pytest.raises(TypeError):
        bus.metrics.gauge("c")     # name already registered as a counter


def test_metrics_noop_when_disabled():
    bus = Bus()
    bus.count("c")
    bus.gauge("g", 1.0)
    bus.observe("h", 1.0)
    assert bus.metrics.snapshot() == {}


def test_capture_attaches_and_detaches_default_bus():
    assert not BUS.active
    with capture() as sink:
        assert BUS.active
        BUS.emit("inside")
    assert not BUS.active
    assert sink.kinds() == ["inside"]


def test_emit_is_thread_safe():
    bus = Bus()
    sink = bus.attach(MemorySink())
    n, threads = 200, []
    for t in range(4):
        th = threading.Thread(
            target=lambda: [bus.emit("k") for _ in range(n)])
        threads.append(th)
        th.start()
    for th in threads:
        th.join()
    assert len(sink.events) == 4 * n
    assert sorted(e["seq"] for e in sink.events) == list(range(4 * n))


# ---------------------------------------------------------------------------
def test_jsonl_round_trip(tmp_path):
    path = tmp_path / "events.jsonl"
    bus = Bus()
    sink = bus.attach(JsonlSink(str(path)))
    bus.emit("round.end", round=0, dur=0.25, frozen_ids=[1, 2])
    bus.emit("search.tell", round=0, budget=123.5)
    sink.close()

    lines = path.read_text().strip().splitlines()
    header = json.loads(lines[0])
    assert header["kind"] == "obs.meta"
    assert header["v"] == SCHEMA_VERSION

    events = read_jsonl(str(path))
    assert [e["kind"] for e in events] == ["round.end", "search.tell"]
    assert events[0]["frozen_ids"] == [1, 2]
    assert events[1]["budget"] == 123.5


def test_jsonl_unjsonable_payload_degrades_to_repr(tmp_path):
    path = tmp_path / "e.jsonl"
    bus = Bus()
    sink = bus.attach(JsonlSink(str(path)))
    bus.emit("k", weird=object())
    sink.close()
    (ev,) = read_jsonl(str(path))
    assert ev["kind"] == "k" and "object" in ev["weird"]


def test_jsonl_tolerates_torn_tail(tmp_path):
    path = tmp_path / "e.jsonl"
    bus = Bus()
    sink = bus.attach(JsonlSink(str(path)))
    bus.emit("ok")
    sink.flush()
    with open(path, "a") as fh:
        fh.write('{"kind": "torn", "half')    # live log mid-write
    events = read_jsonl(str(path))
    assert [e["kind"] for e in events] == ["ok"]
    sink.close()


def test_jsonl_version_check(tmp_path):
    path = tmp_path / "e.jsonl"
    path.write_text('{"kind": "obs.meta", "v": 999}\n{"kind": "x"}\n')
    with pytest.raises(ValueError, match="schema"):
        read_jsonl(str(path))
    assert [e["kind"] for e in read_jsonl(str(path),
                                          require_version=False)] == ["x"]
    (tmp_path / "none.jsonl").write_text('{"kind": "x"}\n')
    with pytest.raises(ValueError, match="header"):
        read_jsonl(str(tmp_path / "none.jsonl"))
