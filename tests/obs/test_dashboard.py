"""The live campaign dashboard: /campaign snapshots (including
mid-search progress via chunked rounds), SSE replay, /metrics, and the
port-in-use fallback inherited from HttpEndpoint."""
import http.client
import json
import urllib.request

import numpy as np
import pytest

from repro.dse import SuccessiveHalving, SweepSpec, memoize_build, run_search
from repro.obs import Bus, CampaignServer, CampaignStats
from repro.sims.memsys import build

MAX_H = 2000.0


def _get(port, path):
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}{path}", timeout=5) as r:
        return r.status, json.loads(r.read().decode())


# ---------------------------------------------------------------------------
def test_stats_aggregation_from_synthetic_events():
    st = CampaignStats()
    st.on_event({"kind": "search.start", "ts": 1.0, "seq": 0,
                 "driver": "SuccessiveHalving", "objective": ["est_finish"],
                 "cycle_budget": 5000.0})
    st.on_event({"kind": "round.end", "ts": 2.0, "seq": 1, "epochs": 40,
                 "survivors": 3, "pending": 5, "pool": 8})
    st.on_event({"kind": "compile", "ts": 2.1, "seq": 2, "n": 2,
                 "dur": 0.5})
    st.on_event({"kind": "transfer", "ts": 2.2, "seq": 3, "dur": 0.01})
    st.on_event({"kind": "search.tell", "ts": 3.0, "seq": 4, "round": 0,
                 "n": 8, "budget": 800.0, "best": {"x": 1}})
    st.on_event({"kind": "rung.promote", "ts": 3.1, "seq": 5, "bracket": 0,
                 "rung": 0, "horizon": 60.0, "promoted": 3, "dropped": 5,
                 "warm": False, "spent": 480.0, "replay_cycles": 480.0})
    snap = st.snapshot()
    assert snap["events"] == 6
    assert snap["rounds_drained"] == 1
    assert snap["lanes"] == {"live": 3, "pending": 5, "pool": 8}
    assert snap["epochs"]["total"] == 40
    assert snap["compiles"] == {"count": 2, "dur_total": 0.5}
    assert snap["transfers"]["count"] == 1
    s = snap["search"]
    assert s["driver"] == "SuccessiveHalving" and not s["done"]
    assert s["round"] == 1 and s["trials"] == 8 and s["budget"] == 800.0
    assert s["best"] == {"x": 1}
    assert snap["cycles"]["cap"] == 5000.0
    assert snap["cycles"]["remaining"] == 4200.0
    assert snap["cycles"]["burn_fraction"] == pytest.approx(0.16)
    assert len(snap["promotions"]) == 1
    st.on_event({"kind": "search.end", "ts": 4.0, "seq": 6,
                 "best": {"x": 2}})
    assert st.snapshot()["search"]["done"]
    assert st.snapshot()["search"]["best"] == {"x": 2}


def test_cache_and_shard_sections_aggregate():
    st = CampaignStats()
    st.on_event({"kind": "cache.enable", "ts": 1.0, "seq": 0,
                 "dir": "/tmp/c", "jax": "0.4.37"})
    st.on_event({"kind": "cache.miss", "ts": 1.1, "seq": 1,
                 "what": "tuned_top", "key": "k", "bytes": 0})
    st.on_event({"kind": "cache.write", "ts": 1.2, "seq": 2,
                 "what": "tuned_top", "key": "k", "bytes": 11})
    st.on_event({"kind": "cache.hit", "ts": 1.3, "seq": 3,
                 "what": "tuned_top", "key": "k", "bytes": 11})
    st.on_event({"kind": "cache.hit", "ts": 1.4, "seq": 4,
                 "what": "rungs", "key": "k2", "bytes": 7})
    st.on_event({"kind": "rounds.start", "ts": 1.5, "seq": 5, "B": 64,
                 "ladder": [32], "quantum": 128, "shard": 2,
                 "per_lane": False, "autotune": False})
    st.on_event({"kind": "shard.rebalance", "ts": 2.0, "seq": 6,
                 "round": 3, "shards": 2, "moved": 5, "lanes": 30})
    st.on_event({"kind": "shard.rebalance", "ts": 2.1, "seq": 7,
                 "round": 4, "shards": 2, "moved": 2, "lanes": 28})
    snap = st.snapshot()
    c = snap["cache"]
    assert c["hits"] == 2 and c["misses"] == 1 and c["writes"] == 1
    assert c["hit_rate"] == pytest.approx(2 / 3)
    assert c["bytes_read"] == 18 and c["bytes_written"] == 11
    assert c["dir"] == "/tmp/c"
    s = snap["shards"]
    assert s == {"devices": 2, "rebalances": 2, "lanes_moved": 7}


def test_unknown_kinds_only_bump_the_event_counter():
    st = CampaignStats()
    st.on_event({"kind": "totally.new", "ts": 1.0, "seq": 0})
    snap = st.snapshot()
    assert snap["events"] == 1 and snap["rounds_drained"] == 0


# ---------------------------------------------------------------------------
def test_campaign_endpoint_reports_live_progress_mid_search():
    """A halving search over the memsys grid drains through chunked
    rounds; polling /campaign after every tell must show monotone
    progress *while the search is still running*."""
    srv = CampaignServer(port=0)       # default bus: what the stack emits to
    try:
        bf = memoize_build(
            lambda: build(n_cores=3, pattern="mixed", n_reqs=6,
                          donate=True))
        sim, st = bf()
        total = int(np.sum(np.asarray(st.comp_state["core"]["remaining"])))

        def extract(sim, s):
            rem = int(np.sum(
                np.asarray(s.comp_state["core"]["remaining"])))
            vt = float(s.time)
            return {"virtual_time": vt, "remaining": rem,
                    "est_finish": vt * total / max(total - rem, 1)}

        pool = SweepSpec.grid(
            {"conn_latency[-1]": [10., 20., 30., 40.],
             "kind.l1.extra_hit_rate": [0.0, 0.4, 0.8]})

        mid = []

        def poll(driver):
            _, snap = _get(srv.port, "/campaign")
            mid.append(snap)

        drv = SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                                min_horizon=60.0, eta=3, seed=0)
        res = run_search(bf, drv, extract=extract, chunk=4, callback=poll)

        assert len(mid) == res.rounds >= 2
        # mid-flight snapshots: the first poll sees a live, not-done
        # search with budget already burning; progress is monotone
        assert mid[0]["search"]["driver"] == "SuccessiveHalving"
        assert not mid[0]["search"]["done"]
        assert mid[0]["search"]["budget"] > 0.0
        assert mid[0]["rounds_drained"] >= 1
        rounds = [m["search"]["round"] for m in mid]
        assert rounds == sorted(rounds) and rounds[0] == 1
        budgets = [m["search"]["budget"] for m in mid]
        assert budgets == sorted(budgets)
        assert budgets[-1] == pytest.approx(res.budget)
        trials = [m["search"]["trials"] for m in mid]
        assert trials[-1] == len(res.rows)

        # after the run: done, and the winner is reported
        _, final = _get(srv.port, "/campaign")
        assert final["search"]["done"]
        assert final["search"]["best"] == res.best
        assert final["promotions"]
    finally:
        srv.close()


def test_events_sse_replays_ring():
    bus = Bus()
    srv = CampaignServer(bus=bus, port=0)
    try:
        bus.emit("round.end", round=0, epochs=4, survivors=1,
                 pending=0, pool=0)
        bus.emit("sweep.end", n_points=1, groups=1, dur=0.1)

        conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=5)
        conn.request("GET", "/events")
        resp = conn.getresponse()
        assert resp.status == 200
        assert resp.getheader("Content-Type") == "text/event-stream"
        got = []
        while len(got) < 2:
            line = resp.fp.readline()
            if line.startswith(b"data: "):
                got.append(json.loads(line[len(b"data: "):]))
        assert [e["kind"] for e in got] == ["round.end", "sweep.end"]

        # live event after connect also arrives on the open stream
        bus.emit("search.tell", round=0, n=1, budget=10.0)
        while True:
            line = resp.fp.readline()
            if line.startswith(b"data: "):
                ev = json.loads(line[len(b"data: "):])
                break
        assert ev["kind"] == "search.tell"
        conn.close()
    finally:
        srv.close()


def test_metrics_index_and_404():
    bus = Bus()
    srv = CampaignServer(bus=bus, port=0)
    try:
        bus.count("dse.rounds", 3)
        code, body = _get(srv.port, "/metrics")
        assert code == 200 and body["dse.rounds"] == 3.0

        with urllib.request.urlopen(srv.url, timeout=5) as r:
            page = r.read().decode()
        assert "campaign" in page and "/campaign" in page

        with pytest.raises(urllib.error.HTTPError) as err:
            _get(srv.port, "/nope")
        assert err.value.code == 404
    finally:
        srv.close()


def test_port_in_use_falls_back_to_ephemeral():
    a = CampaignServer(bus=Bus(), port=0)
    try:
        b = CampaignServer(bus=Bus(), port=a.port)
        try:
            assert b.port != a.port        # rebound, not crashed
            assert b.endpoint.requested_port == a.port
            code, _ = _get(b.port, "/campaign")
            assert code == 200
        finally:
            b.close()
    finally:
        a.close()


def test_close_detaches_and_releases():
    bus = Bus()
    srv = CampaignServer(bus=bus, port=0)
    assert bus.active
    port = srv.port
    srv.close()
    assert not bus.active
    srv.close()                            # idempotent
    with pytest.raises(OSError):
        urllib.request.urlopen(
            f"http://127.0.0.1:{port}/campaign", timeout=1)
