"""Chrome-trace (Perfetto) export and the Daisen-lite campaign HTML:
format validity of every emitted trace event, campaign/engine process
split, the JSONL-path input, and the engine-task bridge."""
import json

import numpy as np
import pytest

from repro.core.tracing import TracingDomain
from repro.dse import SuccessiveHalving, SweepSpec, memoize_build, run_search
from repro.obs import (Bus, JsonlSink, bridge_domain, campaign_tasks,
                       capture, export_campaign_html, export_chrome_trace,
                       to_chrome_trace)
from repro.sims.memsys import build

MAX_H = 2000.0


def _validate_chrome_trace(trace):
    """Assert the trace-event-format invariants Perfetto's importer
    relies on (the JSON Array/Object format spec)."""
    assert isinstance(trace, dict)
    evs = trace["traceEvents"]
    assert isinstance(evs, list) and evs
    for ev in evs:
        assert isinstance(ev["ph"], str) and ev["ph"] in "XiCM", ev
        assert isinstance(ev["name"], str) and ev["name"]
        assert isinstance(ev["pid"], int)
        assert isinstance(ev["tid"], int)
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0, ev
        if ev["ph"] == "i":
            assert ev["s"] in ("g", "p", "t")
        if ev["ph"] == "M":
            assert ev["name"] in ("process_name", "thread_name")
            assert "name" in ev["args"]
        if "args" in ev:
            json.dumps(ev["args"])      # args must be JSON-serializable
    return evs


@pytest.fixture(scope="module")
def campaign_events(tmp_path_factory):
    """One real halving campaign captured to memory + JSONL."""
    bf = memoize_build(lambda: build(n_cores=3, pattern="mixed", n_reqs=6,
                                     donate=True))
    sim, st = bf()
    total = int(np.sum(np.asarray(st.comp_state["core"]["remaining"])))

    def extract(sim, s):
        rem = int(np.sum(np.asarray(s.comp_state["core"]["remaining"])))
        vt = float(s.time)
        return {"virtual_time": vt, "remaining": rem,
                "est_finish": vt * total / max(total - rem, 1)}

    pool = SweepSpec.grid({"conn_latency[-1]": [10., 20., 30., 40.],
                           "kind.l1.extra_hit_rate": [0.0, 0.4, 0.8]})
    path = tmp_path_factory.mktemp("pf") / "campaign.jsonl"
    from repro.obs import BUS
    sink = BUS.attach(JsonlSink(str(path)))
    try:
        with capture() as mem:
            drv = SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                                    min_horizon=60.0, eta=3, seed=0)
            run_search(bf, drv, extract=extract, chunk=4)
    finally:
        BUS.detach(sink)
        sink.close()
    return mem.events, str(path)


def test_campaign_trace_validates_and_covers_activity(campaign_events):
    events, _ = campaign_events
    trace = to_chrome_trace(events)
    evs = _validate_chrome_trace(trace)

    # the campaign process is named, with the expected named tracks
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert "dse-campaign" in procs
    tracks = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"rounds", "compile", "transfer", "search",
            "trials"} <= tracks
    assert any(t.startswith("bracket") for t in tracks)

    names = [e["name"] for e in evs]
    assert any(n.startswith("round ") for n in names)
    assert any(n.startswith("search round") for n in names)
    assert any("promote" in n for n in names)
    # counter tracks render the burn-down / lane occupancy
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"budget", "lanes"} <= counters
    # search-round slices pair ask->tell: positive duration, budget args
    slices = [e for e in evs if e["name"].startswith("search round")]
    assert slices and all(s["dur"] > 0 for s in slices)
    assert all("budget" in s["args"] for s in slices)


def test_export_accepts_jsonl_path(campaign_events, tmp_path):
    events, jsonl = campaign_events
    out = export_chrome_trace(jsonl, str(tmp_path / "trace.json"))
    with open(out) as fh:
        trace = json.load(fh)
    _validate_chrome_trace(trace)
    # the file-based export matches the in-memory one event-for-event
    assert [e["name"] for e in trace["traceEvents"]] == \
        [e["name"] for e in to_chrome_trace(events)["traceEvents"]]


def test_engine_task_bridge_lands_in_engine_process():
    bus = Bus()
    dom = TracingDomain("engine")
    tracer = bridge_domain(dom, bus=bus, clock="virtual")
    with capture(bus) as mem:
        with dom.task("inst", "load", "Core0"):
            with dom.task("mem", "read", "L1[0]"):
                pass
    dom.detach(tracer)

    tasks = mem.of("task")
    assert len(tasks) == 2
    assert {t["location"] for t in tasks} == {"Core0", "L1[0]"}
    assert all(t["clock"] == "virtual" for t in tasks)
    child = [t for t in tasks if t["location"] == "L1[0]"][0]
    parent = [t for t in tasks if t["location"] == "Core0"][0]
    assert child["parent_id"] == parent["id"]

    evs = _validate_chrome_trace(to_chrome_trace(mem.events))
    engine = [e for e in evs if e["pid"] == 2 and e["ph"] == "X"]
    assert len(engine) == 2
    assert {e["name"] for e in engine} == {"inst/load", "mem/read"}
    assert len({e["tid"] for e in engine}) == 2    # one track per location
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"
             and e["pid"] == 2}
    assert procs == {"engine"}


def test_bridge_is_inert_without_sinks():
    bus = Bus()
    dom = TracingDomain("engine")
    bridge_domain(dom, bus=bus)
    with dom.task("a", "b", "c"):
        pass
    assert bus.seq == 0


# ---------------------------------------------------------------------------
def test_campaign_tasks_rebased_for_daisen(campaign_events):
    events, _ = campaign_events
    tasks = campaign_tasks(events)
    assert tasks
    starts = [t.start for t in tasks]
    assert min(starts) >= 0.0                      # rebased to first ts
    assert all(t.end >= t.start for t in tasks)
    locs = {t.location for t in tasks}
    assert {"rounds", "search", "transfer"} <= locs


def test_export_campaign_html(campaign_events, tmp_path):
    events, jsonl = campaign_events
    out = export_campaign_html(events, str(tmp_path / "c.html"),
                               title="halving campaign")
    doc = open(out).read()
    assert "Daisen-lite" in doc and "halving campaign" in doc
    assert "rounds" in doc
    # the JSONL path works as input too
    out2 = export_campaign_html(jsonl, str(tmp_path / "c2.html"))
    assert "Daisen-lite" in open(out2).read()
