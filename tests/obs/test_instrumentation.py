"""The DSE stack on the bus: a telemetry-on sweep/search emits the
schema-v1 event catalogue, telemetry-off materializes zero events and
leaves results bit-identical, and a halving campaign over the grid
writes a readable JSONL log (the ISSUE acceptance path)."""
import numpy as np
import pytest

from repro.dse import (Objective, SuccessiveHalving, SweepSpec,
                       memoize_build, run_search, run_sweep)
from repro.obs import BUS, JsonlSink, capture, read_jsonl
from repro.sims.memsys import build

MAX_H = 2000.0


@pytest.fixture(scope="module")
def ctx():
    def build_fn():
        return build(n_cores=3, pattern="mixed", n_reqs=6, donate=True)

    bf = memoize_build(build_fn)
    sim, st = bf()
    total = int(np.sum(np.asarray(st.comp_state["core"]["remaining"])))

    def extract(sim, s):
        rem = int(np.sum(np.asarray(s.comp_state["core"]["remaining"])))
        vt = float(s.time)
        done = total - rem
        return {"virtual_time": vt, "remaining": rem,
                "est_finish": vt * total / max(done, 1)}

    pool = SweepSpec.grid({"conn_latency[-1]": [10., 20., 30., 40.],
                           "kind.l1.extra_hit_rate": [0.0, 0.4, 0.8]})
    return bf, extract, pool


def _rows_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra.keys() == rb.keys()
        for k in ra:
            if isinstance(ra[k], float):
                assert ra[k] == rb[k], k      # bit-identical, not approx
            else:
                assert ra[k] == rb[k], k


# ---------------------------------------------------------------------------
def test_sweep_emits_catalogue_and_stays_bit_identical(ctx):
    bf, extract, pool = ctx
    spec = SweepSpec.grid({"conn_latency[-1]": [10., 20.]})
    kw = dict(until=300.0, extract=extract, chunk=2)

    rows_off = run_sweep(bf, spec, **kw)
    seq0 = BUS.seq
    rows_off2 = run_sweep(bf, spec, **kw)
    assert BUS.seq == seq0            # disabled: zero events materialized

    with capture() as sink:
        rows_on = run_sweep(bf, spec, **kw)
    _rows_equal(rows_off, rows_on)    # telemetry never changes results
    _rows_equal(rows_off, rows_off2)

    kinds = set(sink.kinds())
    assert {"sweep.start", "sweep.group", "rounds.start", "round.end",
            "rounds.end", "transfer", "sweep.end"} <= kinds
    (start,) = sink.of("sweep.start")
    assert start["n_points"] == 2
    assert start["axes"]["axes"]["conn_latency[-1]"] == 2
    (end,) = sink.of("sweep.end")
    assert end["n_points"] == 2 and end["dur"] > 0.0
    # every round.end carries the live/pending/epoch accounting
    for ev in sink.of("round.end"):
        for key in ("round", "rung", "dur", "live", "epochs", "finished",
                    "survivors", "pending", "pool", "quantum"):
            assert key in ev, key
    (rend,) = sink.of("rounds.end")
    assert rend["B"] == 2
    # transfers: liveness pulls plus the final rows pull
    whats = {e["what"] for e in sink.of("transfer")}
    assert "rows" in whats
    # events are seq-ordered and schema-flat
    seqs = [e["seq"] for e in sink.events]
    assert seqs == sorted(seqs)


def test_metrics_registry_populated_by_sweep(ctx):
    bf, extract, pool = ctx
    spec = SweepSpec.grid({"conn_latency[-1]": [10., 20.]})
    BUS.metrics.clear()
    with capture():
        run_sweep(bf, spec, until=300.0, extract=extract, chunk=2)
        snap = BUS.metrics.snapshot()
    assert snap["dse.sweeps"] >= 1.0
    assert snap["dse.rounds"] >= 1.0
    assert snap["dse.round_s"]["count"] >= 1
    assert snap["dse.transfer.rows_s"]["count"] >= 1


# ---------------------------------------------------------------------------
def test_halving_search_emits_full_trace_and_jsonl(ctx, tmp_path):
    """The acceptance path: a halving search over the memsys grid with a
    JSONL sink produces a versioned event log covering ask/tell rounds,
    per-trial spend, and rung promotions."""
    bf, extract, pool = ctx
    path = tmp_path / "campaign.jsonl"
    sink = JsonlSink(str(path))
    BUS.attach(sink)
    try:
        with capture() as mem:
            drv = SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                                    min_horizon=60.0, eta=3, seed=0)
            res = run_search(bf, drv, extract=extract, chunk=4)
    finally:
        BUS.detach(sink)
        sink.close()

    assert res.best is not None

    kinds = set(mem.kinds())
    assert {"search.start", "search.ask", "trial", "search.tell",
            "rung.promote", "search.end"} <= kinds

    (start,) = mem.of("search.start")
    assert start["driver"] == "SuccessiveHalving"
    assert start["resumed_round"] == 0

    asks = mem.of("search.ask")
    tells = mem.of("search.tell")
    assert len(asks) == len(tells) == res.rounds
    assert [e["round"] for e in asks] == list(range(res.rounds))

    trials = mem.of("trial")
    assert len(trials) == len(res.rows)
    # round-0 trials are always cold and pay real cycles; promoted
    # configs that already finished may legitimately charge 0
    assert all(t["cycles"] > 0 for t in trials if t["round"] == 0)
    assert all(t["cycles"] >= 0 for t in trials)
    spend = sum(t["cycles"] for t in trials)
    assert spend == pytest.approx(res.budget, rel=1e-6)

    promos = mem.of("rung.promote")
    assert promos, "halving must report promotions"
    for ev in promos:
        assert ev["promoted"] + ev["dropped"] == ev["n"]
        if not ev["final"]:
            assert len(ev["promoted_points"]) == min(ev["promoted"], 8)

    (end,) = mem.of("search.end")
    assert end["trials"] == len(res.rows)
    assert end["budget"] == pytest.approx(res.budget)
    assert end["best"] == res.best

    # ... and the identical stream landed durably in the JSONL log
    logged = read_jsonl(str(path))
    assert [e["kind"] for e in logged] == mem.kinds()
    assert logged[-1]["kind"] == "search.end"


def test_warm_promotion_reports_cost_savings(ctx):
    """Warm halving's rung.promote events expose warm-vs-cold cost:
    spent (actual incremental charge) < replay_cycles (cold replay)."""
    bf, extract, pool = ctx
    with capture() as mem:
        drv = SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                                min_horizon=60.0, eta=3, seed=0, warm=True)
        run_search(bf, drv, extract=extract, chunk=4)
    later = [e for e in mem.of("rung.promote") if e["rung"] > 0]
    assert later
    for ev in later:
        assert ev["warm"] is True
        assert ev["spent"] is not None
        assert ev["spent"] < ev["replay_cycles"]


def test_search_disabled_is_silent_and_identical(ctx):
    bf, extract, pool = ctx

    def go():
        drv = SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                                min_horizon=60.0, eta=3, seed=0)
        return run_search(bf, drv, extract=extract, chunk=4)

    seq0 = BUS.seq
    r_off = go()
    assert BUS.seq == seq0
    with capture():
        r_on = go()
    assert r_off.best == r_on.best
    assert r_off.budget == r_on.budget
    _rows_equal(r_off.rows, r_on.rows)
