"""Substrate tests: data determinism/sharding, checkpoint roundtrip +
resharding + async + keep-k, optimizer correctness, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data import DataPipeline, synthetic_batch
from repro.optim import adamw_init, adamw_update
from repro.optim.compress import dequant_int8, quant_int8


# --- data ------------------------------------------------------------------
def test_data_deterministic_and_restart_safe():
    cfg = get_smoke_config("stablelm-1.6b")
    a = synthetic_batch(cfg, 8, 32, seed=1, step=7)
    b = synthetic_batch(cfg, 8, 32, seed=1, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(cfg, 8, 32, seed=1, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_shards_disjoint_and_elastic():
    cfg = get_smoke_config("stablelm-1.6b")
    full = synthetic_batch(cfg, 8, 16, seed=0, step=3)
    # 2-way and 4-way shardings reconstruct the same global batch
    for world in (2, 4):
        parts = [synthetic_batch(cfg, 8, 16, seed=0, step=3, rank=r,
                                 world=world)["tokens"] for r in range(world)]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])


def test_text_pipeline():
    cfg = get_smoke_config("stablelm-1.6b")
    dp = DataPipeline.from_text(cfg, "hello world, " * 500, batch=4, seq=16)
    b1, b2 = dp(0), dp(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].max() < cfg.vocab


# --- checkpoint --------------------------------------------------------------
def _tree():
    return {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "nest": {"b": jnp.ones((5,), jnp.float32)},
            "count": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), t, step=3)
    out, manifest = restore_checkpoint(str(tmp_path), t)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in range(5):
        mgr.save(_tree(), s)
    mgr.wait()
    from repro.ckpt.checkpoint import list_steps
    assert list_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_reshard(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), t, step=0)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    out, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
    assert out["w"].sharding == sh["w"]


# --- optimizer ---------------------------------------------------------------
def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    opt = adamw_init(params)
    for _ in range(300):
        g = {"x": 2 * (params["x"] - target)}
        params, opt = adamw_update(g, opt, params, lr=3e-2, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=0.05)


def test_adamw_int8_moments_close_to_fp32():
    target = jnp.asarray([0.5, -1.5, 2.5, -3.5])
    outs = {}
    for md in ("float32", "int8"):
        params = {"x": jnp.zeros(4)}
        opt = adamw_init(params, moments_dtype=md)
        for _ in range(200):
            g = {"x": 2 * (params["x"] - target)}
            params, opt = adamw_update(g, opt, params, lr=3e-2,
                                       weight_decay=0.0, moments_dtype=md)
        outs[md] = np.asarray(params["x"])
    np.testing.assert_allclose(outs["int8"], outs["float32"], atol=0.2)


def test_int8_quant_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quant_int8(g)
    err = np.abs(np.asarray(dequant_int8(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_int8_allreduce_error_feedback_converges():
    """Compressed DP training still converges on a quadratic (shard_map)."""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.core.pdes import shard_map_compat
    from repro.optim.compress import int8_allreduce_grads

    mesh = jax.make_mesh((1,), ("data",))
    target = jnp.asarray([1.0, -1.0])
    params = jnp.zeros(2)
    err = {"x": jnp.zeros(2)}

    for _ in range(150):
        @partial(shard_map_compat, mesh=mesh, in_specs=(P(), P(), P()),
                 out_specs=(P(), P()))
        def reduced(p, e, t):
            g = {"x": 2 * (p - t)}
            red, ne = int8_allreduce_grads(g, {"x": e}, mesh, axes=("data",))
            return red["x"], ne["x"]

        g, err_x = reduced(params, err["x"], target)
        err = {"x": err_x}
        params = params - 3e-2 * g
    np.testing.assert_allclose(np.asarray(params), np.asarray(target),
                               atol=0.05)
