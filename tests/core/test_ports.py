"""Ring-buffer port properties (hypothesis): FIFO order, capacity limits,
no phantom messages, send/recv round-trips."""
import jax.numpy as jnp
import numpy as np
import pytest

try:                           # optional: only the property test needs it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core.message import MSG_WORDS, msg_new
from repro.core.ports import Ports


def _empty(P=1, CAP=4):
    return Ports(
        in_buf=jnp.zeros((P, CAP, MSG_WORDS), jnp.int32),
        in_head=jnp.zeros((P,), jnp.int32),
        in_cnt=jnp.zeros((P,), jnp.int32),
        out_buf=jnp.zeros((P, CAP, MSG_WORDS), jnp.int32),
        out_head=jnp.zeros((P,), jnp.int32),
        out_cnt=jnp.zeros((P,), jnp.int32),
        cap=jnp.full((P,), CAP, jnp.int32),
        gid=jnp.arange(P, dtype=jnp.int32),
        peer=jnp.full((P,), -1, jnp.int32),
        t=jnp.float32(0.0),
    )


def _check_out_ring_fifo_and_capacity(ops, cap):
    """Random send(payload=i) sequences: never exceed cap; contents FIFO."""
    p = _empty(CAP=4)
    p = Ports(**{**p.__dict__, "cap": jnp.full((1,), cap, jnp.int32)})
    model = []                     # reference queue
    sent_seq = 0
    for op in ops:
        if op == 0:   # send
            p2, ok = p.send(0, msg_new(1, p0=sent_seq))
            if len(model) < cap:
                assert bool(ok)
                model.append(sent_seq)
            else:
                assert not bool(ok)
            p = p2
            sent_seq += 1
        else:         # connection-side pop (head of out ring)
            if model:
                head = p.out_buf[0, p.out_head[0]]
                assert int(head[4]) == model.pop(0)
                p = Ports(**{**p.__dict__,
                             "out_head": (p.out_head + 1) % 4,
                             "out_cnt": p.out_cnt - 1})
        assert int(p.out_cnt[0]) == len(model)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(ops=st.lists(st.integers(0, 1), min_size=1, max_size=24),
           cap=st.integers(1, 4))
    def test_out_ring_fifo_and_capacity(ops, cap):
        _check_out_ring_fifo_and_capacity(ops, cap)
else:
    def test_out_ring_fifo_and_capacity():
        _check_out_ring_fifo_and_capacity([0, 0, 1, 0, 1, 1, 0, 0, 0, 1], 2)
        pytest.importorskip("hypothesis")


def test_recv_respects_ready_time():
    from repro.core.message import W_TIME, f2i
    p = _empty()
    m = msg_new(1, p0=7).at[W_TIME].set(f2i(5.0))
    p = Ports(**{**p.__dict__,
                 "in_buf": p.in_buf.at[0, 0].set(m),
                 "in_cnt": p.in_cnt.at[0].set(1)})
    msg, ok, p2 = p.recv(0)                 # t=0 < ready=5
    assert not bool(ok) and int(p2.in_cnt[0]) == 1
    p = Ports(**{**p.__dict__, "t": jnp.float32(5.0)})
    msg, ok, p2 = p.recv(0)
    assert bool(ok) and int(msg[4]) == 7 and int(p2.in_cnt[0]) == 0


def test_send_fills_src_and_default_peer():
    p = _empty()
    p = Ports(**{**p.__dict__, "peer": jnp.full((1,), 42, jnp.int32),
                 "gid": jnp.full((1,), 7, jnp.int32)})
    p2, ok = p.send(0, msg_new(1))
    assert bool(ok)
    head = p2.out_buf[0, 0]
    assert int(head[1]) == 7 and int(head[2]) == 42
