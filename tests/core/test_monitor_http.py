"""Monitor HTTP endpoint hardening: ephemeral-port fallback, the
/status and /bottlenecks routes, and clean idempotent shutdown."""
import json
import urllib.error
import urllib.request

import pytest

from repro.core.monitor import HttpEndpoint, Monitor
from repro.sims.memsys import build


def _get(port, path):
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}{path}", timeout=5) as r:
        return json.loads(r.read().decode())


@pytest.fixture
def mon():
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4)
    m = Monitor(sim, st, http_port=0)
    yield m
    m.shutdown()


def test_http_status_and_bottlenecks(mon):
    assert mon.http_port and mon.http_port > 0
    stat = _get(mon.http_port, "/status")
    for key in ("virtual_time", "epochs", "ticks", "progress_ratio",
                "pending_messages"):
        assert key in stat, key
    assert _get(mon.http_port, "/bottlenecks") == []   # nothing ran yet

    mon.state = mon.sim.run(mon.state, until=5.0)
    stat = _get(mon.http_port, "/status")
    assert stat["epochs"] > 0


def test_port_in_use_falls_back_to_ephemeral(mon):
    """A second monitor requesting the same port must come up on an
    ephemeral port and report the actually-bound one."""
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4)
    m2 = Monitor(sim, st, http_port=mon.http_port)
    try:
        assert m2.http_port is not None
        assert m2.http_port != mon.http_port
        assert m2._httpd.requested_port == mon.http_port
        assert "virtual_time" in _get(m2.http_port, "/status")
        # the original monitor is undisturbed
        assert "virtual_time" in _get(mon.http_port, "/status")
    finally:
        m2.shutdown()


def test_shutdown_releases_port_and_is_idempotent(mon):
    port = mon.http_port
    mon.shutdown()
    assert mon.http_port is None and mon._httpd is None
    with pytest.raises((urllib.error.URLError, OSError)):
        urllib.request.urlopen(
            f"http://127.0.0.1:{port}/status", timeout=1)
    mon.shutdown()                      # second call is a no-op
    mon.close()                         # alias too


def test_monitor_without_http_shutdown_is_safe():
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4)
    m = Monitor(sim, st)                # no endpoint requested
    assert m.http_port is None
    m.shutdown()


def test_endpoint_ephemeral_rebind_reuses_handler():
    from http.server import BaseHTTPRequestHandler

    class H(BaseHTTPRequestHandler):
        def do_GET(self):
            body = b'{"ok": true}'
            self.send_response(200)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):
            pass

    a = HttpEndpoint(H, port=0)
    try:
        b = HttpEndpoint(H, port=a.port)      # occupied -> ephemeral
        try:
            assert b.port != a.port
            assert b.requested_port == a.port
            assert b.url.endswith(str(b.port))
            assert _get(b.port, "/")["ok"] is True
        finally:
            b.shutdown()
    finally:
        a.shutdown()
