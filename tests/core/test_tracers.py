"""DBTracer persistence contract: sqlite flush/fetch round-trips for
tasks *and* metrics (exact field fidelity), the CSV backend, and
flush_engine_trace's counters on a real memsys run."""
import csv
import sqlite3

from repro.core.tracers import DBTracer, flush_engine_trace
from repro.core.tracing import Task, TracingDomain


def _clock():
    t = {"v": 0.0}

    def fn():
        t["v"] += 1.0
        return t["v"]

    return fn


def test_sqlite_task_round_trip_preserves_every_field(tmp_path):
    dom = TracingDomain("t", time_fn=_clock())
    db = dom.attach(DBTracer(str(tmp_path / "t.db"), run_id="rt"))
    with dom.task("inst", "load $2,[$4]", "Core0") as t1:
        dom.tag_task("issued")
        with dom.task("mem", "read", "L1[0]") as t2:
            dom.tag_task("hit")
            t2.details["bank"] = 3
    db.flush()

    got = {t.id: t for t in db.fetch_tasks()}
    assert set(got) == {t1.id, t2.id}
    r1, r2 = got[t1.id], got[t2.id]
    assert (r1.category, r1.action, r1.location) == \
        ("inst", "load $2,[$4]", "Core0")
    assert r1.parent_id == "" and r2.parent_id == t1.id
    assert r1.start == t1.start and r1.end == t1.end
    assert r1.tags == ["issued"] and r2.tags == ["hit"]
    assert r2.details == {"bank": 3}

    # unfinished tasks round-trip end=None through the -1 sentinel
    open_task = Task(id="x", parent_id="", category="c", action="a",
                     location="l", start=9.0, end=None)
    db.on_end(open_task)
    db.flush()
    assert [t.end for t in db.fetch_tasks() if t.id == "x"] == [None]
    db.close()


def test_sqlite_metrics_round_trip_and_run_table(tmp_path):
    path = tmp_path / "m.db"
    db = DBTracer(str(path), run_id="runA")
    db.add_metric("buf_level", "l1.p0", 1.0, 3.0)
    db.add_metrics([("buf_level", "l1.p1", 2.0, 4.0),
                    ("busy_ticks", "core[0]", 2.0, 17.0)])
    db.flush()
    assert db.fetch_metrics("buf_level") == [
        ("buf_level", "l1.p0", 1.0, 3.0),
        ("buf_level", "l1.p1", 2.0, 4.0)]
    assert len(db.fetch_metrics()) == 3            # no filter: everything
    db.close()

    # the file is a plain sqlite DB another process can open: run row
    # carries the run_id, metrics carry it per row
    conn = sqlite3.connect(str(path))
    assert conn.execute("SELECT run_id FROM runs").fetchone() == ("runA",)
    assert conn.execute(
        "SELECT DISTINCT run_id FROM metrics").fetchall() == [("runA",)]
    conn.close()


def test_csv_backend_round_trip(tmp_path):
    path = tmp_path / "t.csv"
    dom = TracingDomain("t", time_fn=_clock())
    db = dom.attach(DBTracer(str(path), backend="csv"))
    with dom.task("a", "act", "loc"):
        pass
    db.close()
    with open(path, newline="") as fh:
        rows = list(csv.DictReader(fh))
    assert len(rows) == 1
    assert rows[0]["category"] == "a" and rows[0]["location"] == "loc"
    assert float(rows[0]["end"]) > float(rows[0]["start"])


def test_flush_engine_trace_on_memsys_run(tmp_path):
    from repro.sims.memsys import build, finish_stats
    sim, st = build(n_cores=3, pattern="mixed", n_reqs=8,
                    sample_period=8.0)
    final = sim.run(st, until=5000.0)
    assert finish_stats(sim, final)["remaining"] == 0

    db = DBTracer(str(tmp_path / "engine.db"))
    flush_engine_trace(sim, final, db)

    busy = db.fetch_metrics("busy_ticks")
    # one busy counter per component instance, each non-negative
    n_comp = sum(k.n_instances for k in sim.kinds)
    assert len(busy) == n_comp
    assert all(v >= 0.0 for *_, v in busy)
    assert any(v > 0.0 for *_, v in busy)          # the sim did work

    levels = db.fetch_metrics("buf_level")
    assert levels                                   # sampling ran
    # sampled series timestamps are positive multiples of the period
    ts = sorted({t for _, _, t, _ in levels})
    assert ts[0] > 0.0
    locs = {loc for _, loc, _, _ in levels}
    assert any(loc.startswith("core[") for loc in locs)
    db.close()
