"""Equivalence + donation guarantees for the engine's performance paths.

The hot loop has three build-time performance axes (super-epoch fusion,
donated zero-copy stepping, segmented per-kind port state — ENGINE_PERF.md);
this suite pins the invariant that none of them may change results: a fused
+ donated run must produce *bit-identical* ``Stats``, final virtual time and
component state versus the K=1 non-donated compatibility path, across every
memsys workload pattern.  It also proves the donation contract itself: a
donating ``run()`` releases the input state's buffers (true zero-copy), a
non-donating build keeps them alive, and ``copy_state`` makes an input
survive donation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sims.memsys import build, finish_stats

PATTERNS = ["compute", "stream", "pointer", "idle_half", "mixed"]

STAT_FIELDS = ("epochs", "ticks", "progress_ticks", "delivered")


def _assert_states_identical(a, b):
    assert float(a.time) == float(b.time)
    for f in STAT_FIELDS:
        assert int(getattr(a.stats, f)) == int(getattr(b.stats, f)), f
    np.testing.assert_array_equal(np.asarray(a.stats.busy),
                                  np.asarray(b.stats.busy))
    np.testing.assert_array_equal(np.asarray(a.next_tick),
                                  np.asarray(b.next_tick))
    for kname in a.comp_state:
        for la, lb in zip(jax.tree.leaves(a.comp_state[kname]),
                          jax.tree.leaves(b.comp_state[kname])):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for seg_a, seg_b in ((a.in_cnt, b.in_cnt), (a.out_cnt, b.out_cnt)):
        for kname in seg_a:
            np.testing.assert_array_equal(np.asarray(seg_a[kname]),
                                          np.asarray(seg_b[kname]))


@pytest.mark.parametrize("pattern", PATTERNS)
def test_fused_donated_matches_k1_reference(pattern):
    ref_sim, ref_st = build(n_cores=4, pattern=pattern, n_reqs=12,
                            super_epoch=1, donate=False)
    ref = ref_sim.run(ref_st, until=20000.0)
    fus_sim, fus_st = build(n_cores=4, pattern=pattern, n_reqs=12,
                            super_epoch=6, donate=True)
    out = fus_sim.run(fus_st, until=20000.0)
    _assert_states_identical(out, ref)
    # the workload actually completed (equivalence on a dead sim is vacuous)
    assert finish_stats(fus_sim, out)["remaining"] == 0


@pytest.mark.parametrize("super_epoch", [2, 3, 8])
def test_fusion_width_is_observation_invariant(super_epoch):
    ref_sim, ref_st = build(n_cores=3, pattern="mixed", n_reqs=8,
                            super_epoch=1, donate=False)
    ref = ref_sim.run(ref_st, until=20000.0)
    sim, st = build(n_cores=3, pattern="mixed", n_reqs=8,
                    super_epoch=super_epoch, donate=False)
    _assert_states_identical(sim.run(st, until=20000.0), ref)


def test_donation_releases_input_buffers():
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4)
    out = sim.run(st, until=100.0)
    # the donated input must not be retained — big ring buffers, small
    # count arrays, component state and scalars are all given up
    assert st.next_tick.is_deleted()
    assert all(v.is_deleted() for v in st.in_buf.values())
    assert all(v.is_deleted() for v in st.out_buf.values())
    assert all(v.is_deleted() for v in st.in_cnt.values())
    assert st.stats.epochs.is_deleted()
    # the returned state is alive and chains into the next run
    out2 = sim.run(out, until=200.0)
    assert float(out2.time) >= float(0.0)


def test_copy_state_survives_donation():
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4)
    keep = sim.copy_state(st)
    out = sim.run(st, until=5000.0)
    assert st.next_tick.is_deleted()
    assert not keep.next_tick.is_deleted()
    # the copy replays to the same result
    out2 = sim.run(keep, until=5000.0)
    _assert_states_identical(out, out2)


def test_reusing_consumed_state_raises_clear_error():
    """A second use of a donated input must raise an actionable error up
    front (pointing at copy_state / donate=False), not surface as XLA's
    opaque deleted-buffer failure mid-dispatch."""
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4)
    out = sim.run(st, until=100.0)
    with pytest.raises(RuntimeError, match="copy_state"):
        sim.run(st, until=200.0)
    with pytest.raises(RuntimeError, match="donate=False"):
        sim.run(st, until=200.0)
    # the returned state still chains normally
    out2 = sim.run(out, until=200.0)
    assert float(out2.time) >= 0.0


def test_no_donate_build_keeps_input_reusable():
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4, donate=False)
    out = sim.run(st, until=5000.0)
    assert not st.next_tick.is_deleted()
    out2 = sim.run(st, until=5000.0)          # same input, second run
    _assert_states_identical(out, out2)


def test_set_default_peers_after_warmed_up_run():
    """``set_default_peers`` must take effect even after the jitted run has
    already been traced and executed (the re-wrap discards traces that baked
    the old peer constants)."""
    from repro.sims.memsys import build_memsys, finish_stats

    n = 3
    sim, st = build_memsys(n_cores=n, pattern="stream", n_reqs=6,
                           donate=False)
    # warm the jit with the *unpatched* peers: on the multi-member crossbar
    # the l1 memory ports have no default peer, so misses are never
    # addressed to the DRAM and the workload stalls
    warm = sim.run(st, until=20000.0)
    assert finish_stats(sim, warm)["remaining"] > 0

    # rewrite the default peers on the warmed-up simulation...
    dram_pid = sim.port_id("dram", 0, 0)
    sim.set_default_peers(
        {sim.port_id("l1", i, 1): dram_pid for i in range(n)})
    out = sim.run(st, until=20000.0)

    # ...and the rerun must be bit-identical to a freshly patched build
    ref_sim, ref_st = build(n_cores=n, pattern="stream", n_reqs=6,
                            donate=False)
    ref = ref_sim.run(ref_st, until=20000.0)
    _assert_states_identical(out, ref)
    assert finish_stats(sim, out)["remaining"] == 0
