"""Engine behaviour tests: Smart Ticking rules, Availability Backpropagation,
crossbar arbitration, event-driven sleep, and the smart==naive equivalence
property (hypothesis) that underwrites the paper's "<1% error" claim (we
require *exact* equality — conservative wakeups lose nothing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                           # optional: only the property test needs it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import (ComponentKind, SimBuilder, TickResult, msg_new,
                        payload)


# ---------------------------------------------------------------------------
# Reusable component kinds
# ---------------------------------------------------------------------------
def producer_tick(state, ports, t):
    want = state["remaining"] > 0
    ports, ok = ports.send(
        0, msg_new(1, dst=state["dst"], p0=state["sent"]), when=want)
    oki = ok.astype(jnp.int32)
    return ({"remaining": state["remaining"] - oki,
             "sent": state["sent"] + oki, "dst": state["dst"]},
            ports, TickResult.make(ok))


def forwarder_tick(state, ports, t):
    # receive on port 0, forward on port 1; only recv when we can send.
    can = ports.can_send(1)
    msg, ok, ports = ports.recv(0, when=can)
    ports, sent = ports.send(1, msg_new(1, p0=payload(msg, 0)), when=ok)
    return ({"seen": state["seen"] + ok.astype(jnp.int32)},
            ports, TickResult.make(ok))


def consumer_tick(state, ports, t):
    msg, ok, ports = ports.recv(0)
    oki = ok.astype(jnp.int32)
    return ({"received": state["received"] + oki,
             "sum": state["sum"] + oki * payload(msg, 0),
             "last_t": jnp.where(ok, t, state["last_t"])},
            ports, TickResult.make(ok))


def make_producer(n, remaining, dst=None):
    dst = jnp.full((n,), -1, jnp.int32) if dst is None else jnp.asarray(dst)
    return ComponentKind(
        "producer", producer_tick, n, 1,
        {"remaining": jnp.asarray(remaining, jnp.int32),
         "sent": jnp.zeros(n, jnp.int32), "dst": dst})


def make_consumer(n, period=1.0, cap=4):
    return ComponentKind(
        "consumer", consumer_tick, n, 1,
        {"received": jnp.zeros(n, jnp.int32), "sum": jnp.zeros(n, jnp.int32),
         "last_t": jnp.full(n, -1.0, jnp.float32)}, period=period, cap=cap)


# ---------------------------------------------------------------------------
def test_basic_pipeline_and_event_skip():
    b = SimBuilder()
    p = b.add_kind(make_producer(1, [5]))
    c = b.add_kind(make_consumer(1))
    b.connect([p.port(0, 0), c.port(0, 0)], latency=1.0)
    sim = b.build()
    s = sim.run(sim.init_state(), until=1000.0)
    assert s.comp_state["consumer"]["received"].item() == 5
    assert s.comp_state["consumer"]["sum"].item() == 0 + 1 + 2 + 3 + 4
    # Smart Ticking: far fewer epochs than the 1000-cycle horizon (rule 3).
    assert s.stats.epochs.item() < 20


def test_rule1_arrival_wakes_sleeping_consumer():
    b = SimBuilder()
    p = b.add_kind(make_producer(1, [1]))
    c = b.add_kind(make_consumer(1))
    c_kind = b.kinds[1]
    c_kind.start_asleep = True  # consumer never self-starts
    b.connect([p.port(0, 0), c.port(0, 0)], latency=3.0)
    sim = b.build()
    s = sim.run(sim.init_state(), until=100.0)
    assert s.comp_state["consumer"]["received"].item() == 1
    # arrival = send(t=0) delivered at t=1 epoch... message leaves producer at
    # t=0, the connection moves it at t=1 with latency 3 => arrival t=4.
    assert s.comp_state["consumer"]["last_t"].item() == pytest.approx(4.0)


def test_rule2_backpressure_wakes_producer():
    # consumer drains 1 msg / 4 cycles; producer cap 1 => stalls, must be
    # woken by out-buffer full->not-full transitions (availability backprop).
    b = SimBuilder()
    p = b.add_kind(make_producer(1, [6]))
    b.kinds[0].cap = 1
    c = b.add_kind(make_consumer(1, period=4.0, cap=1))
    b.connect([p.port(0, 0), c.port(0, 0)], latency=1.0)
    sim = b.build()
    s = sim.run(sim.init_state(), until=2000.0)
    assert s.comp_state["consumer"]["received"].item() == 6
    assert s.comp_state["producer"]["sent"].item() == 6
    # Throughput limited by the consumer: >= 6*4 cycles of virtual time.
    assert s.comp_state["consumer"]["last_t"].item() >= 20.0


def test_availability_backprop_chain():
    # producer -> forwarder -> consumer, consumer slow, tiny buffers:
    # the full->not-full chain must propagate two hops upstream (Fig. 5).
    b = SimBuilder()
    p = b.add_kind(make_producer(1, [8]))
    b.kinds[0].cap = 1
    f = b.add_kind(ComponentKind(
        "forwarder", forwarder_tick, 1, 2,
        {"seen": jnp.zeros(1, jnp.int32)}, cap=1))
    c = b.add_kind(make_consumer(1, period=5.0, cap=1))
    b.connect([p.port(0, 0), f.port(0, 0)], latency=1.0)
    b.connect([f.port(0, 1), c.port(0, 0)], latency=1.0)
    sim = b.build()
    s = sim.run(sim.init_state(), until=5000.0)
    assert s.comp_state["consumer"]["received"].item() == 8
    assert s.comp_state["forwarder"]["seen"].item() == 8
    assert s.comp_state["consumer"]["sum"].item() == sum(range(8))


def test_crossbar_round_robin_fairness():
    # 3 producers feed 1 consumer through a single multi-port connection:
    # Akita's "connection as round-robin arbitrated crossbar".
    b = SimBuilder()
    p = b.add_kind(make_producer(3, [10, 10, 10]))
    c = b.add_kind(make_consumer(1))
    b.connect([p.port(0, 0), p.port(1, 0), p.port(2, 0), c.port(0, 0)],
              latency=1.0)
    sim = b.build()
    # explicit destination: multi-member connections have no default peer
    st = sim.init_state()
    dst = jnp.full((3,), sim.port_id("consumer", 0, 0), jnp.int32)
    st.comp_state["producer"]["dst"] = dst
    s = sim.run(st, until=2000.0)
    assert s.comp_state["consumer"]["received"].item() == 30
    # all three producers finished => arbitration served everyone
    assert s.comp_state["producer"]["sent"].tolist() == [10, 10, 10]


def test_sleep_until_event_driven():
    # A component that does one action every 100 cycles using next_time —
    # the pure event-driven mode (TrioSim-style fast-forward).
    def timer_tick(state, ports, t):
        fire = t + 1e-3 >= state["next_fire"]
        st = {"count": state["count"] + fire.astype(jnp.int32),
              "next_fire": jnp.where(fire, state["next_fire"] + 100.0,
                                     state["next_fire"])}
        return st, ports, TickResult.make(fire, next_time=st["next_fire"])

    b = SimBuilder()
    b.add_kind(ComponentKind(
        "timer", timer_tick, 1, 1,
        {"count": jnp.zeros(1, jnp.int32),
         "next_fire": jnp.zeros(1, jnp.float32)}))
    sim = b.build()
    s = sim.run(sim.init_state(), until=1000.0)
    assert s.comp_state["timer"]["count"].item() == 11  # t=0,100,...,1000
    assert s.stats.epochs.item() <= 12  # event-driven: one epoch per firing


def test_message_conservation_under_tiny_buffers():
    b = SimBuilder()
    p = b.add_kind(make_producer(4, [7, 3, 9, 1]))
    b.kinds[0].cap = 1
    c = b.add_kind(make_consumer(4, period=3.0, cap=1))
    for i in range(4):
        b.connect([p.port(i, 0), c.port(i, 0)], latency=2.0)
    sim = b.build()
    s = sim.run(sim.init_state(), until=3000.0)
    assert s.comp_state["consumer"]["received"].tolist() == [7, 3, 9, 1]
    assert s.stats.delivered.item() == 20


# ---------------------------------------------------------------------------
# Property: smart == naive, exactly (paper Fig. 9b, strengthened to 0 error).
# ---------------------------------------------------------------------------
def _build_random(n_stage, n_lane, counts, caps, cons_period, latency, naive):
    b = SimBuilder()
    p = b.add_kind(make_producer(n_lane, counts))
    b.kinds[0].cap = caps[0]
    stages = []
    for si in range(n_stage):
        k = ComponentKind(
            f"fwd{si}", forwarder_tick, n_lane, 2,
            {"seen": jnp.zeros(n_lane, jnp.int32)}, cap=caps[1])
        stages.append(b.add_kind(k))
    c = b.add_kind(make_consumer(n_lane, period=float(cons_period),
                                 cap=caps[2]))
    for lane in range(n_lane):
        chain = [p.port(lane, 0)]
        for s in stages:
            chain += [s.port(lane, 0), s.port(lane, 1)]
        chain += [c.port(lane, 0)]
        for a, bb in zip(chain[::2], chain[1::2]):
            b.connect([a, bb], latency=float(latency))
    return b.build(naive=naive)


def _check_smart_equals_naive(n_stage, n_lane, seed, cap0, cap1, cap2,
                              cons_period, latency):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 8, size=n_lane).tolist()
    horizon = 400.0
    results = []
    for naive in (False, True):
        sim = _build_random(n_stage, n_lane, counts, (cap0, cap1, cap2),
                            cons_period, latency, naive)
        s = sim.run(sim.init_state(), until=horizon)
        results.append(s)
    smart, naive_s = results
    # Exact equality of all component state + per-component progress counts.
    for kname in smart.comp_state:
        for leaf_a, leaf_b in zip(
                jax.tree.leaves(smart.comp_state[kname]),
                jax.tree.leaves(naive_s.comp_state[kname])):
            np.testing.assert_array_equal(np.asarray(leaf_a),
                                          np.asarray(leaf_b))
    np.testing.assert_array_equal(np.asarray(smart.stats.busy),
                                  np.asarray(naive_s.stats.busy))
    assert smart.stats.delivered.item() == naive_s.stats.delivered.item()
    assert smart.stats.progress_ticks.item() == naive_s.stats.progress_ticks.item()
    # and Smart Ticking actually skips work:
    assert smart.stats.ticks.item() <= naive_s.stats.ticks.item()


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(
        n_stage=st.integers(0, 3),
        n_lane=st.integers(1, 3),
        seed=st.integers(0, 2 ** 31 - 1),
        cap0=st.integers(1, 3), cap1=st.integers(1, 3),
        cap2=st.integers(1, 3),
        cons_period=st.integers(1, 4),
        latency=st.integers(1, 3),
    )
    def test_smart_equals_naive(n_stage, n_lane, seed, cap0, cap1, cap2,
                                cons_period, latency):
        _check_smart_equals_naive(n_stage, n_lane, seed, cap0, cap1, cap2,
                                  cons_period, latency)
else:
    def test_smart_equals_naive():
        """One fixed example when hypothesis is unavailable; the full
        property run skips (satellite: collection must not abort)."""
        _check_smart_equals_naive(2, 2, 1234, 1, 2, 1, 3, 2)
        pytest.importorskip("hypothesis")
