"""Tracing system tests: task tree, tracers, DB, backtraces, engine flush,
monitor + bottleneck analyzer, Daisen export."""
import os

import jax.numpy as jnp
import pytest

from repro.core import daisen
from repro.core.monitor import Monitor
from repro.core.tracers import (AverageTimeTracer, BusyTimeTracer, DBTracer,
                                TagCountTracer, TotalTimeTracer,
                                flush_engine_trace)
from repro.core.tracing import TracingDomain, format_backtrace


def _clock():
    t = {"v": 0.0}

    def fn():
        t["v"] += 1.0
        return t["v"]

    return fn


def test_task_tree_and_tracers():
    dom = TracingDomain("t", time_fn=_clock())
    tot = dom.attach(TotalTimeTracer())
    avg = dom.attach(AverageTimeTracer(),
                     filter=lambda t: t.category == "mem")
    busy = dom.attach(BusyTimeTracer())
    tags = dom.attach(TagCountTracer())
    with dom.task("inst", "load", "core0") as t1:
        dom.tag_task("issued")
        with dom.task("mem", "read", "l1") as t2:
            dom.tag_task("cache-hit")
            assert t2.parent_id == t1.id
    assert tot.metrics()["count"] == 2
    assert avg.metrics()["count"] == 1            # filter applied
    assert tags.metrics() == {"issued": 1, "cache-hit": 1}
    assert busy.metrics()["core0"] > 0


def test_db_tracer_and_daisen_export(tmp_path):
    dom = TracingDomain("t", time_fn=_clock())
    db = dom.attach(DBTracer(str(tmp_path / "trace.db"), run_id="r1"))
    with dom.task("step", "train", "loop"):
        with dom.task("mem", "read", "l1"):
            pass
    db.flush()
    tasks = db.fetch_tasks()
    assert len(tasks) == 2
    child = [t for t in tasks if t.category == "mem"][0]
    parent = [t for t in tasks if t.category == "step"][0]
    assert child.parent_id == parent.id
    out = daisen.export_db(db, str(tmp_path / "trace.html"))
    html = open(out).read()
    assert "Daisen-lite" in html and "l1" in html
    db.add_metric("buf_level", "l1.p0", 1.0, 3.0)
    assert db.fetch_metrics("buf_level")[0][3] == 3.0
    db.close()


def test_csv_tracer(tmp_path):
    dom = TracingDomain("t", time_fn=_clock())
    db = dom.attach(DBTracer(str(tmp_path / "trace.csv"), backend="csv"))
    with dom.task("a", "b", "c"):
        pass
    db.close()
    lines = open(tmp_path / "trace.csv").read().splitlines()
    assert len(lines) == 2 and lines[0].startswith("id,")


def test_enhanced_backtrace():
    dom = TracingDomain("t", time_fn=_clock())
    try:
        with dom.task("inst", "load $2,[$4]", "Core3"):
            with dom.task("translation", "vaddr 0x1000", "MMU"):
                raise RuntimeError("Page entry not found")
    except RuntimeError:
        pass
    # after unwinding, a fresh backtrace is empty; format chain directly
    bt = format_backtrace(header="Panic: page fault", chain=[])
    assert bt.startswith("Panic")


def test_backtrace_renders_chain(capsys):
    dom = TracingDomain("t", time_fn=_clock())
    with pytest.raises(RuntimeError):
        with dom.task("inst", "load", "Core3"):
            with dom.task("translation", "vaddr", "MMU"):
                raise RuntimeError("boom")
    out = capsys.readouterr().out
    assert "@Core3, inst, load" in out
    assert "@MMU, translation, vaddr" in out


def test_engine_flush_and_monitor(tmp_path):
    from repro.sims.memsys import build, finish_stats
    sim, st = build(n_cores=4, pattern="mixed", n_reqs=16,
                    sample_period=16.0)
    mon = Monitor(sim, st)
    final, hung = mon.run_monitored(until=5000.0, chunk=500.0, verbose=False)
    assert not hung
    assert finish_stats(sim, final)["remaining"] == 0
    dom = TracingDomain("t")
    db = DBTracer(str(tmp_path / "engine.db"))
    flush_engine_trace(sim, final, db)
    assert len(db.fetch_metrics("busy_ticks")) > 0
    assert len(db.fetch_metrics("buf_level")) > 0
    db.close()


def test_monitor_detects_hang():
    """A consumer that never drains (cap-1 producer into sleeping consumer
    kind that refuses to pop) should be flagged by the bottleneck analyzer."""
    from repro.core import ComponentKind, SimBuilder, TickResult, msg_new

    def stuck_tick(state, ports, t):
        return state, ports, TickResult.make(jnp.asarray(False))

    def spammer_tick(state, ports, t):
        ports, ok = ports.send(0, msg_new(1), when=state["n"] > 0)
        return {"n": state["n"] - ok.astype(jnp.int32)}, ports, \
            TickResult.make(ok)

    b = SimBuilder()
    sp = b.add_kind(ComponentKind("spam", spammer_tick, 1, 1,
                                  {"n": jnp.full(1, 8, jnp.int32)}, cap=1))
    stk = b.add_kind(ComponentKind("stuck", stuck_tick, 1, 1,
                                   {"_": jnp.zeros(1, jnp.int32)}, cap=1))
    b.connect([sp.port(0, 0), stk.port(0, 0)], latency=1.0)
    sim = b.build()
    mon = Monitor(sim, sim.init_state())
    _, hung = mon.run_monitored(until=10000.0, chunk=100.0, hang_chunks=2,
                                verbose=False)
    assert hung
    rows = mon.bottleneck_report()
    assert any("stuck" in r["port"] and r["stalled_consumer"] for r in rows)


def test_monitor_inspect_and_force_tick():
    from repro.sims.memsys import build
    sim, st = build(n_cores=2, pattern="mixed", n_reqs=4)
    mon = Monitor(sim, st)
    mon.state = sim.run(st, until=10.0)
    fields = mon.inspect("core", 0)
    assert "remaining" in fields
    stat = mon.force_tick("core", 0)
    assert stat["epochs"] >= 1
