"""Regression: Daisen-lite HTML export must survive hostile task
strings — `</script>` or markup in category/action/location used to
terminate the embedded JSON mid-page (template injection)."""
import json

from repro.core.daisen import _embed_json, export_html
from repro.core.tracing import Task


def _task(**kw):
    base = dict(id="t1", parent_id="", category="c", action="a",
                location="loc", start=0.0, end=1.0)
    base.update(kw)
    return Task(**base)


def test_embed_json_neutralizes_markup():
    s = _embed_json({"x": "</script><script>alert(1)</script>"})
    assert "</script>" not in s and "<" not in s and ">" not in s
    assert json.loads(s) == {"x": "</script><script>alert(1)</script>"}
    # & escaped too (guards against HTML entity interpretation)
    assert "&" not in _embed_json({"x": "a&b"})
    assert json.loads(_embed_json({"x": "a&b"})) == {"x": "a&b"}


def test_export_html_with_hostile_strings(tmp_path):
    evil = "</script><script>alert('xss')</script>"
    tasks = [
        _task(id="t1", category=evil, action="a", location="core0"),
        _task(id="t2", category="c", action=evil, location="core0",
              start=1.0, end=2.0),
        _task(id="t3", category="c", action="a", location=evil,
              start=2.0, end=3.0, tags=[evil]),
    ]
    out = export_html(tasks, str(tmp_path / "trace.html"),
                      title="run " + evil)
    doc = open(out).read()
    # exactly the template's own script open/close tags survive — the
    # payload never terminates the script element early, and the title
    # never introduces an executable script element
    assert doc.count("</script>") == 1
    assert doc.count("<script>") == 1
    # the payload is still recoverable from the embedded JSON
    payload = doc.split("const TASKS = ", 1)[1].split(";\n", 1)[0]
    rows = json.loads(payload)
    assert rows[0]["category"] == evil
    assert rows[2]["tags"] == [evil]


def test_export_html_with_literal_placeholder_text(tmp_path):
    """A task string containing the template placeholders themselves
    must not corrupt the substitution (positional split, not sequential
    replace)."""
    tasks = [_task(category="__TASKS__", action="__TITLE__")]
    out = export_html(tasks, str(tmp_path / "t.html"),
                      title="__TASKS__ weird")
    doc = open(out).read()
    payload = doc.split("const TASKS = ", 1)[1].split(";\n", 1)[0]
    rows = json.loads(payload)
    assert rows[0]["category"] == "__TASKS__"
    assert rows[0]["action"] == "__TITLE__"
    assert "__TASKS__ weird" in doc                # title rendered


def test_export_html_escapes_title(tmp_path):
    out = export_html([_task()], str(tmp_path / "t.html"),
                      title="<img src=x onerror=alert(1)>")
    doc = open(out).read()
    assert "<img" not in doc
    assert "&lt;img" in doc
