"""Continuous batching == sequential per-request decoding (greedy)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.serve.engine import ServeEngine


def _cfg():
    return dataclasses.replace(get_smoke_config("stablelm-1.6b"),
                               n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=2, head_dim=16, d_ff=64, vocab=64)


def _reference(params, cfg, prompt, n_new):
    seq = jnp.asarray(prompt, jnp.int32)[None, :]
    out = []
    for _ in range(n_new):
        logits, _, _ = tfm.forward(params, cfg, {"tokens": seq},
                                   mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        seq = jnp.concatenate([seq, jnp.asarray([[nxt]], jnp.int32)], axis=1)
    return out


def test_continuous_batching_matches_sequential():
    cfg = _cfg()
    params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=3, max_len=32)
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10]]
    n_new = 6
    for p in prompts:
        eng.submit(p, max_new=n_new)
    done = eng.run_until_idle()
    assert len(done) == 3
    by_prompt = {tuple(r.prompt.tolist()): r.out for r in done}
    for p in prompts:
        ref = _reference(params, cfg, p, n_new)
        assert by_prompt[tuple(p)] == ref, (p, by_prompt[tuple(p)], ref)


def test_more_requests_than_slots():
    cfg = _cfg()
    params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=24)
    rids = [eng.submit([i + 1, i + 2], max_new=4) for i in range(5)]
    done = eng.run_until_idle()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert all(len(r.out) == 4 for r in done)


def test_idle_engine_sleeps():
    cfg = _cfg()
    params = init_params(tfm.model_specs(cfg), jax.random.PRNGKey(2))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=16)
    assert eng.step() == []   # no device work when idle (Smart Ticking)
