"""Structural (topology-shape) sweep throughput: a masked topology family
versus the per-shape rebuild+recompile workflow it replaces (DSE.md
"Topology families").

A 4-point ``shape.core`` ∈ {1, 2, 4, 8} sweep of the memsys hierarchy:

* ``rebuild_baseline`` — one ``build(n_cores=S)`` + jit compile + run per
  shape: what structural DSE costs when instance counts are build-time
  constants (every ``static.*`` point is its own compile group).
* ``family_cold`` — one padded family build + ONE compile of the vmapped
  masked batch + the batched run (end-to-end, first-call cost).
* ``family_warm`` — the batched run alone (steady-state sweep cost: in a
  DSE campaign the single family compile amortizes across every round;
  the ≥5x CI acceptance bar compares this rate against the rebuild
  baseline, which pays compilation per point forever).

Plus the *cross-process* cold start (DSE.md "Sharded sweeps and the
persistent cache"): two fresh subprocesses share a campaign cache dir
and each times the family build + compile + run from post-import.

* ``family_cold_uncached`` — the first process: every executable is an
  actual XLA compile (persistent-cache misses).
* ``family_cold_cached``   — the second process: every executable
  deserializes from the persistent compilation cache — **zero** misses,
  and the ≥5x CI bar gates the wall-clock ratio against the uncached
  run.
"""
import json
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import jax

from repro.dse import BatchRunner, stack_params, stack_state_list
from repro.sims.memsys import build, build_family

SHAPES = (1, 2, 4, 8)
UNTIL = 50000.0
N_REQS = 24

_COLD_WORKER = textwrap.dedent("""
    import json, time
    import jax
    # persistent-cache traffic: a miss is an actual XLA compile
    from jax._src import monitoring
    C = {"hits": 0, "misses": 0}
    def _l(event):
        if event == "/jax/compilation_cache/cache_hits":
            C["hits"] += 1
        elif event == "/jax/compilation_cache/cache_misses":
            C["misses"] += 1
    monitoring.register_event_listener(lambda e, **kw: _l(e))
    from repro.dse import (BatchRunner, stack_params, stack_state_list,
                           cache as dse_cache)
    from repro.sims.memsys import build_family
    assert dse_cache.active(), "REPRO_CACHE_DIR not picked up"
    SHAPES, UNTIL, N_REQS = %r, %r, %r
    t0 = time.perf_counter()
    fam = build_family(n_cores=max(SHAPES), pattern="mixed",
                       n_reqs=N_REQS, donate=True)
    runner = BatchRunner(fam.sim)
    pb = stack_params([fam.params_for({"core": s}) for s in SHAPES])
    sb = stack_state_list([fam.state_for({"core": s}) for s in SHAPES])
    out = runner.run_batch(sb, pb, UNTIL)
    out.time.block_until_ready()
    dt = time.perf_counter() - t0
    print(json.dumps({"seconds": dt,
                      "rows": [float(t) for t in out.time.tolist()],
                      **C}))
""") % (SHAPES, UNTIL, N_REQS)


def _cold_run(cache_dir):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["REPRO_CACHE_DIR"] = cache_dir
    r = subprocess.run([sys.executable, "-c", _COLD_WORKER],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"cold-start worker failed: {r.stderr[-3000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _family_batch(fam):
    pb = stack_params([fam.params_for({"core": s}) for s in SHAPES])
    sb = stack_state_list([fam.state_for({"core": s}) for s in SHAPES])
    return jax.block_until_ready(sb), jax.block_until_ready(pb)


def bench():
    rows = []
    n = len(SHAPES)

    # baseline: rebuild + recompile + run per shape
    t0 = time.perf_counter()
    for s in SHAPES:
        sim, st = build(n_cores=s, pattern="mixed", n_reqs=N_REQS,
                        donate=True)
        out = sim.run(st, UNTIL)
        out.time.block_until_ready()
    dt_base = time.perf_counter() - t0
    base_cps = n / dt_base
    rows.append({
        "name": "struct_sweep/rebuild_baseline",
        "us_per_call": dt_base / n * 1e6,
        "derived": f"{base_cps:.2f} shapes/s (one build+compile+run per "
                   f"shape, {n}-shape sweep)",
        "configs_per_sec": base_cps,
    })

    # family: one padded build, one compile, every shape a masked lane
    t0 = time.perf_counter()
    fam = build_family(n_cores=max(SHAPES), pattern="mixed", n_reqs=N_REQS,
                       donate=True)
    runner = BatchRunner(fam.sim)
    sb, pb = _family_batch(fam)
    out = runner.run_batch(sb, pb, UNTIL)
    out.time.block_until_ready()
    dt_cold = time.perf_counter() - t0
    rows.append({
        "name": "struct_sweep/family_cold",
        "us_per_call": dt_cold * 1e6,
        "derived": f"{n / dt_cold:.2f} shapes/s incl. the one family "
                   f"build+compile ({(n / dt_cold) / base_cps:.2f}x the "
                   f"rebuild baseline even end-to-end)",
        "configs_per_sec": n / dt_cold,
        "speedup_vs_rebuild": (n / dt_cold) / base_cps,
    })

    sb, pb = _family_batch(fam)     # fresh states; executable is cached
    t0 = time.perf_counter()
    out = runner.run_batch(sb, pb, UNTIL)
    out.time.block_until_ready()
    dt_warm = time.perf_counter() - t0
    warm_cps = n / dt_warm
    rows.append({
        "name": "struct_sweep/family_warm",
        "us_per_call": dt_warm * 1e6,
        "derived": f"{warm_cps:.1f} shapes/s "
                   f"({warm_cps / base_cps:.1f}x per-shape rebuild) "
                   f"[acceptance: >=5x rebuild]",
        "configs_per_sec": warm_cps,
        "speedup_vs_rebuild": warm_cps / base_cps,
    })

    # two-process persistent-cache cold start: same family workload,
    # fresh interpreter each time, shared campaign cache dir
    with tempfile.TemporaryDirectory(prefix="repro_cache_") as cdir:
        uncached = _cold_run(cdir)
        cached = _cold_run(cdir)
    if cached["rows"] != uncached["rows"]:
        raise RuntimeError(
            f"cached cold start changed rows: {cached['rows']} "
            f"vs {uncached['rows']}")
    speedup = uncached["seconds"] / cached["seconds"]
    rows.append({
        "name": "struct_sweep/family_cold_uncached",
        "us_per_call": uncached["seconds"] * 1e6,
        "derived": f"{uncached['seconds']:.2f} s fresh-process family "
                   f"build+compile+run ({uncached['misses']} XLA "
                   f"compiles persisted)",
        "seconds": uncached["seconds"],
        "compile_cache_misses": uncached["misses"],
        "compile_cache_hits": uncached["hits"],
    })
    rows.append({
        "name": "struct_sweep/family_cold_cached",
        "us_per_call": cached["seconds"] * 1e6,
        "derived": f"{cached['seconds']:.2f} s second-process cold start "
                   f"({speedup:.1f}x faster, {cached['misses']} compiles, "
                   f"{cached['hits']} persistent-cache hits) "
                   f"[acceptance: >=5x uncached, zero compiles]",
        "seconds": cached["seconds"],
        "compile_cache_misses": cached["misses"],
        "compile_cache_hits": cached["hits"],
        "speedup_vs_uncached": speedup,
    })
    return rows
