"""Paper Fig. 9a/9b: Smart Ticking speedup + virtual-time accuracy.

Runs each memsys workload to completion with the Smart-Ticking engine, then
replays the same horizon on the naive every-cycle engine.  Reports wall-time
speedup and the virtual-time/statistics error (conservative wakeups make it
exactly 0 — stronger than the paper's <1%)."""
import time

import jax
import numpy as np

from repro.sims.memsys import build, finish_stats

PATTERNS = ["compute", "stream", "pointer", "idle_half", "mixed"]


def _timed_run(sim, st, until):
    # the engine donates its input state, so each run gets a fresh copy
    # (the copy happens outside the timed region)
    out = sim.run(sim.copy_state(st), until=until)   # compile + run
    out.time.block_until_ready()
    st2 = jax.block_until_ready(sim.copy_state(st))
    t0 = time.perf_counter()
    out = sim.run(st2, until=until)
    out.time.block_until_ready()
    return out, time.perf_counter() - t0


def bench(n_cores=16, n_reqs=96):
    rows = []
    for pattern in PATTERNS:
        sim_s, st_s = build(n_cores=n_cores, pattern=pattern, n_reqs=n_reqs)
        out_s = sim_s.run(sim_s.copy_state(st_s), until=100000.0)
        stats_s = finish_stats(sim_s, out_s)
        horizon = float(np.ceil(stats_s["virtual_time"])) + 2
        out_s, dt_s = _timed_run(sim_s, st_s, horizon)
        sim_n, st_n = build(n_cores=n_cores, pattern=pattern, n_reqs=n_reqs,
                            naive=True)
        out_n, dt_n = _timed_run(sim_n, st_n, horizon)
        stats_s = finish_stats(sim_s, out_s)
        stats_n = finish_stats(sim_n, out_n)
        err = 0.0
        for k in ("reads_done", "hits", "misses", "delivered"):
            if stats_n[k]:
                err = max(err, abs(stats_s[k] - stats_n[k]) / stats_n[k])
        rows.append({
            "name": f"smart_ticking/{pattern}",
            "us_per_call": dt_s * 1e6,
            "derived": (f"speedup={dt_n/dt_s:.2f}x "
                        f"epochs {stats_s['epochs']}vs{stats_n['epochs']} "
                        f"stat_err={err*100:.2f}%"),
            "speedup": dt_n / dt_s,
            "stat_err": err,
        })
    gmean = float(np.exp(np.mean([np.log(r["speedup"]) for r in rows])))
    rows.append({"name": "smart_ticking/geomean",
                 "us_per_call": 0.0,
                 "derived": f"speedup={gmean:.2f}x (paper: 2.68x)",
                 "speedup": gmean, "stat_err": 0.0})
    return rows
