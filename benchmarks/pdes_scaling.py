"""§Perf cell C: the sharded conservative-PDES engine itself.

Measures (single-device fallback if only 1 device visible):
  * wall time per simulated virtual cycle vs the conservative lookahead
    window (the classic PDES sync/skew trade-off: larger windows = fewer
    pmin barriers + fewer mailbox exchanges, at the cost of later message
    visibility — correctness is unaffected because inter-shard latency >=
    window);
  * cross-shard collective bytes per simulated cycle from the lowered
    512-chip artifact (the dry-run's own metric).

Run with multiple fake devices for the real measurement:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.run --only pdes_scaling
"""
import time

import jax
import numpy as np


def bench():
    from repro.launch.mesh import make_sim_mesh
    from repro.launch.roofline import parse_collectives
    from repro.sims.memsys import build_sharded_memsys

    n = len(jax.devices())
    mesh = make_sim_mesh(n)
    rows = []
    horizon = 2000.0
    # per-window wire per chip (from the 512-chip dry-run artifact):
    # 256 B collective-permute mailbox + 16 B all-reduce(min) time sync.
    wire_per_window = 272.0
    for lookahead in (4.0, 8.0, 32.0, 128.0):
        ss = build_sharded_memsys(mesh=mesh, n_shards=n, tiles_per_shard=4,
                                  n_reqs=32, lookahead=lookahead)
        st = ss.shard_state(ss.init_state())
        out, _ = ss.run(st, until=horizon, return_windows=True)  # compile
        jax.block_until_ready(out.time)
        t0 = time.perf_counter()
        out, windows = ss.run(st, until=horizon, return_windows=True)
        jax.block_until_ready(out.time)
        dt = time.perf_counter() - t0
        served = int(np.asarray(out.comp_state["dram"]["served"]).sum())
        rows.append({
            "name": f"pdes_scaling/lookahead{int(lookahead)}",
            "us_per_call": dt * 1e6,
            "derived": (f"shards={n} served={served} "
                        f"sync_rounds={windows} "
                        f"coll_bytes/cycle={wire_per_window*windows/horizon:.1f} "
                        f"wall/cycle={dt/horizon*1e6:.1f}us"),
        })
    return rows
