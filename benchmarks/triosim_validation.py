"""Paper Fig. 14: TrioSim step-time validation across DP/TP/PP plans.

The paper validates against a 4×A40 PyTorch platform; offline we validate
the event machinery against the closed-form cost model the traces were
generated from (pipeline bubbles, collective sync and channel contention
all emerge from simulated events, not from the formula)."""
import dataclasses
import time

from repro.configs import get_config
from repro.sims.opgraph import analytic_step_us
from repro.sims.triosim import simulate_step

PLANS = [(4, 1, 1), (1, 4, 1), (1, 1, 4), (2, 2, 1), (1, 2, 2)]


def bench():
    cfg = dataclasses.replace(get_config("stablelm-1.6b"), n_layers=24)
    rows = []
    for dp, tp, pp in PLANS:
        t0 = time.perf_counter()
        r = simulate_step(cfg, batch=16, seq=1024, dp=dp, tp=tp, pp=pp,
                          micro=4)
        dt = time.perf_counter() - t0
        a = analytic_step_us(cfg, 16, 1024, dp, tp, pp, 4)
        rows.append({
            "name": f"triosim/dp{dp}_tp{tp}_pp{pp}",
            "us_per_call": dt * 1e6,
            "derived": (f"sim={r['step_us']/1e3:.1f}ms "
                        f"analytic={a/1e3:.1f}ms "
                        f"ratio={r['step_us']/a:.3f} done={r['done']}"),
        })
    return rows
