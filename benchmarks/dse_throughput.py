"""Design-space exploration throughput: configs/sec for straggler-free
round-based sweeps at B ∈ {1, 8, 64, 256} versus sequential unbatched
runs (memsys, mixed pattern), plus a straggler-heavy **mixed-horizon**
B=256 case (per-lane ``until`` spread ~8x), the **pipelined vs
alternating** round-loop comparison (depth-2 pipeline gated >=1.25x,
bit-identity asserted in-benchmark) and a **two-job LaneMux** case
(two half-size sweeps through one shared loop, rows identical to
their solo runs).

Batched rows run the ``run_rounds`` streaming path ``run_sweep`` uses:
per-lane horizons, epoch-quantum rounds, lane compaction down the chunk
ladder, pending-queue refill, and the one-shot chunk autotune (DSE.md
"Rounds and the chunk ladder").  On a small host the config-axis vmap
saturates well below large B, so monolithic B=256 used to run *below*
shared-jit sequential (0.62x); the ladder streams it at the autotuned
width instead, and compaction reclaims the epochs finished lanes used to
burn.

Sequential baselines bracket what the DSE subsystem buys:

* ``sequential_rebuild`` — the pre-SimParams workflow: every design
  point is its own ``build()`` + jit trace/compile + run (timing knobs
  were baked constants, so N points cost N compiles).  Measured on a
  subsample (it is slow by construction).  The >= 8x B=64 acceptance
  bar compares against this.
* ``sequential_sharedjit`` — sequential runs that already share one
  compiled program via traced params (the engine refactor alone, no
  batching).  The batched speedup over *this* isolates what batching +
  scheduling add; CI gates B=256 (uniform) and the mixed-horizon case
  at >= 1.0x their shared-jit baselines.
"""
import time

import jax
import numpy as np

from repro.dse import (BatchRunner, apply_point, build_param_batch, lane,
                       make_ladder)
from repro.sims.memsys import build

BATCHES = (1, 8, 64, 256)
SEQ_B = 64          # batch size at which the baselines are measured
REBUILD_SAMPLE = 3  # rebuild+recompile baseline subsample (a rate suffices)
UNTIL = 50000.0
N_CORES, N_REQS = 8, 24

MIXED_B = 256       # the straggler-heavy case
MIXED_UNTIL = 1600.0   # top horizon: binds for most configs (~drain time)
MIXED_SPREAD = 8    # per-lane horizons span [MIXED_UNTIL/8, MIXED_UNTIL]
MIXED_SUB = 32      # shared-jit mixed baseline: stratified subsample


def _points(b):
    """b design points spreading crossbar latency and L1 boost."""
    return [{"conn_latency[-1]": 10.0 + (30.0 * i) / max(b - 1, 1),
             "kind.l1.extra_hit_rate": 0.8 * ((i * 7) % b) / max(b - 1, 1)}
            for i in range(b)]


def _mixed_untils(b):
    """Per-lane horizons spread ~MIXED_SPREAD x, decorrelated from the
    param axes (an i*11 stride shuffle) so stragglers land everywhere."""
    lo = MIXED_UNTIL / MIXED_SPREAD
    mix = (np.arange(b) * 11) % b
    return (lo + (MIXED_UNTIL - lo) * mix / max(b - 1, 1)) \
        .astype(np.float32)


TIMED_REPS = 2      # best-of-N timing (the CI box is noisy)


def _timed_rounds(runner, st, pb, until, reps=TIMED_REPS, pipeline=None):
    """Best-of-N timed ``run_rounds`` sweeps (executables pre-warmed)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = runner.run_rounds(st, pb, until, pipeline=pipeline)
        out.time.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def bench(n_cores=N_CORES, n_reqs=N_REQS):
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                    donate=True)
    runner = BatchRunner(sim)
    rows = []

    # baseline 1: rebuild + recompile + run per design point (pre-SimParams
    # reality — each build() re-jits even when shapes match)
    t0 = time.perf_counter()
    for i in range(REBUILD_SAMPLE):
        s_i, st_i = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                          dram_latency=10.0 + 10.0 * i, donate=True)
        out = s_i.run(st_i, UNTIL)
        out.time.block_until_ready()
    dt = time.perf_counter() - t0
    rebuild_cps = REBUILD_SAMPLE / dt
    rows.append({
        "name": "dse_throughput/sequential_rebuild",
        "us_per_call": dt / REBUILD_SAMPLE * 1e6,
        "derived": f"{rebuild_cps:.2f} configs/s (build+compile+run per "
                   f"point, {REBUILD_SAMPLE}-point sample)",
        "configs_per_sec": rebuild_cps,
    })

    # Warm + autotune the streaming path at the largest batch *before*
    # the shared-jit baseline: the gated B256/shared-jit ratio is then
    # numerator and denominator measured back to back — this host's
    # throughput drifts ~2x across minutes, so adjacency matters more
    # than anything else for a stable ratio.
    pb_by_b = {b: build_param_batch(sim, _points(b)) for b in BATCHES}
    b_max = max(BATCHES)
    out = runner.run_rounds(st, pb_by_b[b_max], UNTIL)  # compile+autotune
    out.time.block_until_ready()
    runner.warm_ladder(
        st, pb_by_b[b_max],
        make_ladder(b_max, top=runner._tuned_top.get(1, b_max)))

    # baseline 2: sequential runs sharing one compiled program (traced
    # params, no batching)
    pts = _points(SEQ_B)
    params = [lane(build_param_batch(sim, [p]), 0) for p in pts]
    warm = sim.run(sim.copy_state(st), UNTIL, params=params[0])
    warm.time.block_until_ready()
    dt_seq = float("inf")
    for _ in range(TIMED_REPS):
        states = [jax.block_until_ready(sim.copy_state(st)) for _ in pts]
        t0 = time.perf_counter()
        outs = [sim.run(s, UNTIL, params=p) for s, p in zip(states, params)]
        jax.block_until_ready(outs[-1].time)
        dt_seq = min(dt_seq, time.perf_counter() - t0)
    shared_cps = SEQ_B / dt_seq
    rows.append({
        "name": f"dse_throughput/sequential_sharedjit_B{SEQ_B}",
        "us_per_call": dt_seq * 1e6,
        "derived": f"{shared_cps:.1f} configs/s (one compile, sequential "
                   f"runs: the traced-params win alone)",
        "configs_per_sec": shared_cps,
    })

    # batched rows: the run_rounds streaming path, largest (the gated
    # row, adjacent to its baseline) first.  A first pass per size
    # compiles any remaining rung; warm_ladder pre-compiles every rung
    # the tuned ladder can visit so no timed pass compiles
    # mid-measurement.
    for b in sorted(BATCHES, reverse=True):
        pb = pb_by_b[b]
        out = runner.run_rounds(st, pb, UNTIL)          # warm pass
        out.time.block_until_ready()
        runner.warm_ladder(
            st, pb, make_ladder(b, top=runner._tuned_top.get(1, b)))
        dt = _timed_rounds(runner, st, pb, UNTIL)
        cps = b / dt
        chunk = runner.last_rounds["chunk"]
        row = {
            "name": f"dse_throughput/B{b}",
            "us_per_call": dt * 1e6,
            "derived": f"{cps:.1f} configs/s "
                       f"({cps / rebuild_cps:.1f}x rebuild, "
                       f"{cps / shared_cps:.2f}x shared-jit, "
                       f"chunk {chunk})",
            "configs_per_sec": cps,
            "chunk": chunk,
            "rounds": runner.last_rounds["rounds"],
            "speedup_vs_sequential": cps / rebuild_cps,
            "speedup_vs_sharedjit": cps / shared_cps,
        }
        if b == SEQ_B:
            row["derived"] += " [acceptance: >=8x rebuild]"
        if b == max(BATCHES):
            row["derived"] += " [acceptance: >=1.0x shared-jit]"
        rows.append(row)

    rows.sort(key=lambda r: r["name"])

    # ------------------------------------------------------------------
    # straggler-heavy mixed horizons: per-lane until spread ~8x
    # (baseline and timed sweep measured back to back, as above)
    # ------------------------------------------------------------------
    b = MIXED_B
    pb = pb_by_b[b]
    u = _mixed_untils(b)
    out = runner.run_rounds(st, pb, u)                  # warm pass
    out.time.block_until_ready()
    sub = list(range(0, b, b // MIXED_SUB))[:MIXED_SUB]

    # rebuild baseline at mixed horizons (3-point sample: low/mid/high)
    t0 = time.perf_counter()
    for i in (sub[0], sub[len(sub) // 2], sub[-1]):
        s_i, st_i = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                          dram_latency=10.0 + float(i % 30), donate=True)
        out = s_i.run(st_i, float(u[i]))
        out.time.block_until_ready()
    rebuild_mixed_cps = 3 / (time.perf_counter() - t0)

    # shared-jit sequential baseline at the lanes' own horizons
    # (stratified subsample — a rate is what we need), immediately
    # followed by the timed streaming sweep it gates against
    base = sim.default_params()
    pts_mixed = _points(b)
    sub_params = [apply_point(base, pts_mixed[i]) for i in sub]
    warm = sim.run(sim.copy_state(st), float(u[sub[0]]),
                   params=sub_params[0])
    warm.time.block_until_ready()
    dt = float("inf")
    for _ in range(TIMED_REPS):
        states = [jax.block_until_ready(sim.copy_state(st)) for _ in sub]
        t0 = time.perf_counter()
        outs = [sim.run(s, float(u[i]), params=p)
                for s, i, p in zip(states, sub, sub_params)]
        jax.block_until_ready(outs[-1].time)
        dt = min(dt, time.perf_counter() - t0)
    shared_mixed_cps = len(sub) / dt
    rows.append({
        "name": f"dse_throughput/sequential_sharedjit_mixed{MIXED_SUB}",
        "us_per_call": dt * 1e6,
        "derived": f"{shared_mixed_cps:.1f} configs/s (sequential "
                   f"shared-jit at each lane's own horizon)",
        "configs_per_sec": shared_mixed_cps,
    })

    dt = _timed_rounds(runner, st, pb, u)
    cps = b / dt
    rows.append({
        "name": f"dse_throughput/B{MIXED_B}_mixed_horizon",
        "us_per_call": dt * 1e6,
        "derived": f"{cps:.1f} configs/s "
                   f"({cps / rebuild_mixed_cps:.1f}x rebuild, "
                   f"{cps / shared_mixed_cps:.2f}x shared-jit, "
                   f"chunk {runner.last_rounds['chunk']}, "
                   f"~{MIXED_SPREAD}x horizon spread) "
                   f"[acceptance: >=1.0x shared-jit]",
        "configs_per_sec": cps,
        "chunk": runner.last_rounds["chunk"],
        "rounds": runner.last_rounds["rounds"],
        "speedup_vs_sequential": cps / rebuild_mixed_cps,
        "speedup_vs_sharedjit": cps / shared_mixed_cps,
    })

    # ------------------------------------------------------------------
    # round pipelining: the same mixed-horizon drain with the strictly
    # alternating loop (pipeline=False — the pre-pipelining round loop)
    # vs the depth-2 pipeline, back to back on the same warm
    # executables, with the bit-identity contract asserted in-benchmark
    # ------------------------------------------------------------------
    seq_out = runner.run_rounds(st, pb, u, pipeline=False)
    piped_out = runner.run_rounds(st, pb, u)
    for x, y in zip(jax.tree.leaves(seq_out), jax.tree.leaves(piped_out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    dt_alt = _timed_rounds(runner, st, pb, u, pipeline=False)
    alt_cps = b / dt_alt
    rows.append({
        "name": f"dse_throughput/B{MIXED_B}_mixed_roundloop",
        "us_per_call": dt_alt * 1e6,
        "derived": f"{alt_cps:.1f} configs/s (pipeline=False: the "
                   f"strictly-alternating round loop — the pipelining "
                   f"baseline)",
        "configs_per_sec": alt_cps,
        "rounds": runner.last_rounds["rounds"],
    })
    dt_pip = _timed_rounds(runner, st, pb, u)
    pip_cps = b / dt_pip
    lr = runner.last_rounds
    rows.append({
        "name": f"dse_throughput/B{MIXED_B}_mixed_pipelined",
        "us_per_call": dt_pip * 1e6,
        "derived": f"{pip_cps:.1f} configs/s "
                   f"({pip_cps / alt_cps:.2f}x the alternating round "
                   f"loop, chunk {lr['chunk']}, depth {lr['pipeline']}, "
                   f"bit-identical rows) "
                   f"[acceptance: >=1.25x round loop]",
        "configs_per_sec": pip_cps,
        "chunk": lr["chunk"],
        "rounds": lr["rounds"],
        "pipeline": lr["pipeline"],
        "overlap_frac": lr["overlap_frac"],
        "speedup_vs_roundloop": pip_cps / alt_cps,
        "bit_identical": True,
    })

    # ------------------------------------------------------------------
    # two-job multiplexing: two concurrent half-size mixed-horizon
    # sweeps through one shared round loop (LaneMux) vs running them
    # solo back to back — rows must match the solo runs exactly
    # ------------------------------------------------------------------
    from repro.dse import LaneMux, SweepSpec, memoize_build, run_sweep

    def _mux_build():
        return build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                     donate=True)

    mb = memoize_build(_mux_build)
    pts_all = _points(MIXED_B)
    u_all = _mixed_untils(MIXED_B)
    spec_a = SweepSpec.explicit(pts_all[0::2])
    spec_b = SweepSpec.explicit(pts_all[1::2])
    u_a, u_b = u_all[0::2], u_all[1::2]

    def solo():
        return (run_sweep(mb, spec_a, u_a),
                run_sweep(mb, spec_b, u_b))

    def muxed():
        m = LaneMux()
        m.submit("a", mb, spec_a, u_a)
        m.submit("b", mb, spec_b, u_b)
        got = m.run()
        return got["a"], got["b"]

    solo_rows = solo()                      # warm (B=128 rungs)
    mux_rows = muxed()
    rows_identical = solo_rows == mux_rows  # byte-for-byte row equality
    dt_solo = dt_mux = float("inf")
    for _ in range(TIMED_REPS):
        t0 = time.perf_counter()
        solo()
        dt_solo = min(dt_solo, time.perf_counter() - t0)
        t0 = time.perf_counter()
        muxed()
        dt_mux = min(dt_mux, time.perf_counter() - t0)
    solo_cps = MIXED_B / dt_solo
    mux_cps = MIXED_B / dt_mux
    rows.append({
        "name": f"dse_throughput/two_job_mux_B{MIXED_B // 2}x2",
        "us_per_call": dt_mux * 1e6,
        "derived": f"{mux_cps:.1f} configs/s muxed vs {solo_cps:.1f} "
                   f"solo back-to-back ({mux_cps / solo_cps:.2f}x; "
                   f"rows identical: {rows_identical}) "
                   f"[acceptance: rows identical, >=1.0x solo]",
        "configs_per_sec": mux_cps,
        "solo_configs_per_sec": solo_cps,
        "speedup_vs_solo": mux_cps / solo_cps,
        "rows_identical": bool(rows_identical),
    })
    return rows
