"""Design-space exploration throughput: configs/sec for one vmapped jitted
sweep at B ∈ {1, 8, 64, 256} versus sequential unbatched runs (memsys,
mixed pattern).

Two sequential baselines bracket what the DSE subsystem buys:

* ``sequential_rebuild`` — the pre-SimParams workflow this PR replaces:
  every design point is its own ``build()`` + jit trace/compile + run
  (timing knobs were baked constants, so N points cost N compiles).
  Measured on a subsample (it is slow by construction) and reported as a
  configs/sec rate.  The >= 8x acceptance bar compares against this.
* ``sequential_sharedjit`` — sequential runs that already share one
  compiled program via traced params (this PR's engine refactor alone,
  no batching).  The batched speedup over *this* isolates what the
  config-axis vmap adds (per-epoch overhead amortization; bounded by
  core count on small hosts).
"""
import time

import jax

from repro.dse import BatchRunner, build_param_batch, lane, stack_states
from repro.sims.memsys import build

BATCHES = (1, 8, 64, 256)
SEQ_B = 64          # batch size at which the baselines are measured
REBUILD_SAMPLE = 3  # rebuild+recompile baseline subsample (a rate suffices)
UNTIL = 50000.0
N_CORES, N_REQS = 8, 24


def _points(b):
    """b design points spreading crossbar latency and L1 boost."""
    return [{"conn_latency[-1]": 10.0 + (30.0 * i) / max(b - 1, 1),
             "kind.l1.extra_hit_rate": 0.8 * ((i * 7) % b) / max(b - 1, 1)}
            for i in range(b)]


def bench(n_cores=N_CORES, n_reqs=N_REQS):
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                    donate=True)
    runner = BatchRunner(sim)
    rows = []

    # baseline 1: rebuild + recompile + run per design point (pre-SimParams
    # reality — each build() re-jits even when shapes match)
    t0 = time.perf_counter()
    for i in range(REBUILD_SAMPLE):
        s_i, st_i = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                          dram_latency=10.0 + 10.0 * i, donate=True)
        out = s_i.run(st_i, UNTIL)
        out.time.block_until_ready()
    dt = time.perf_counter() - t0
    rebuild_cps = REBUILD_SAMPLE / dt
    rows.append({
        "name": "dse_throughput/sequential_rebuild",
        "us_per_call": dt / REBUILD_SAMPLE * 1e6,
        "derived": f"{rebuild_cps:.2f} configs/s (build+compile+run per "
                   f"point, {REBUILD_SAMPLE}-point sample)",
        "configs_per_sec": rebuild_cps,
    })

    # baseline 2: sequential runs sharing one compiled program (traced
    # params, no batching)
    pts = _points(SEQ_B)
    params = [lane(build_param_batch(sim, [p]), 0) for p in pts]
    warm = sim.run(sim.copy_state(st), UNTIL, params=params[0])
    warm.time.block_until_ready()
    states = [jax.block_until_ready(sim.copy_state(st)) for _ in pts]
    t0 = time.perf_counter()
    outs = [sim.run(s, UNTIL, params=p) for s, p in zip(states, params)]
    jax.block_until_ready(outs[-1].time)
    dt_seq = time.perf_counter() - t0
    shared_cps = SEQ_B / dt_seq
    rows.append({
        "name": f"dse_throughput/sequential_sharedjit_B{SEQ_B}",
        "us_per_call": dt_seq * 1e6,
        "derived": f"{shared_cps:.1f} configs/s (one compile, sequential "
                   f"runs: the traced-params win alone)",
        "configs_per_sec": shared_cps,
    })

    for b in BATCHES:
        pb = build_param_batch(sim, _points(b))
        out = runner.run_batch(stack_states(st, b), pb, UNTIL)  # compile+run
        out.time.block_until_ready()
        sb = jax.block_until_ready(stack_states(st, b))
        t0 = time.perf_counter()
        out = runner.run_batch(sb, pb, UNTIL)
        out.time.block_until_ready()
        dt = time.perf_counter() - t0
        cps = b / dt
        row = {
            "name": f"dse_throughput/B{b}",
            "us_per_call": dt * 1e6,
            "derived": f"{cps:.1f} configs/s "
                       f"({cps / rebuild_cps:.1f}x rebuild, "
                       f"{cps / shared_cps:.2f}x shared-jit)",
            "configs_per_sec": cps,
            "speedup_vs_sequential": cps / rebuild_cps,
            "speedup_vs_sharedjit": cps / shared_cps,
        }
        if b == SEQ_B:
            row["derived"] += " [acceptance: >=8x rebuild]"
        rows.append(row)
    return rows
