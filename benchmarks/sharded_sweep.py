"""Sharded sweep rounds over a 2-device mesh versus the monolithic pmap
round it replaces (DSE.md "Sharded sweeps and the persistent cache").

The multi-device path is only reachable with >1 device, so the whole
measurement runs in one subprocess with two forced host devices (the
same trick ``tests/dse/test_sharded.py`` uses).  Inside it, on the
straggler-heavy workload of ``dse_throughput`` (B=256, per-lane horizons
spread ~8x):

* ``pmap_monolith`` — lanes laid out ``[2, 128]``, ONE ``jax.pmap`` of
  the vmapped run to every lane's own horizon: the pre-rounds sharding
  story.  Each device iterates until its *slowest* local lane is done,
  finished lanes burn masked epochs, and a drained device idles while
  its neighbour's stragglers grind on.
* ``sharded_rounds`` — ``run_rounds(shard=True)``: one shard_map-of-vmap
  executable per ladder rung across the whole mesh, with the global
  harvest/compact/refill re-packing survivors across shards each round.

Both paths compute bit-identical rows (asserted in the worker); the CI
bar gates the speedup at >= 1.5x.
"""
import json
import os
import subprocess
import sys
import textwrap

_WORKER = textwrap.dedent("""
    import json, time
    import jax
    import numpy as np
    assert jax.local_device_count() == 2, jax.local_device_count()
    from repro.dse import BatchRunner, build_param_batch, stack_states
    from repro.sims.memsys import build

    B, D = 256, 2
    SPREAD = 8       # horizons span [drain/8, ~drain]
    REPS = 2

    sim, st = build(n_cores=8, pattern="mixed", n_reqs=256, donate=True)
    pts = [{"conn_latency[-1]": 10.0 + (30.0 * i) / (B - 1),
            "kind.l1.extra_hit_rate": 0.8 * ((i * 7) % B) / (B - 1)}
           for i in range(B)]
    pb = build_param_batch(sim, pts)

    # per-lane horizons: ~8x spread with a straggler skew — 90% of the
    # lanes stop in the low-horizon band, 10% climb to the workload's
    # drain time (the classic DSE shape: most configs answer quickly, a
    # few pathological ones grind).  An i*11 stride decorrelates the
    # stragglers from the param axes AND from the [2, 128] shard
    # boundary, so the pmap baseline is not rigged: both devices get
    # their fair share of long lanes.
    r0 = BatchRunner(sim)
    probe = r0.run_batch(stack_states(st, 1),
                         jax.tree.map(lambda x: x[:1], pb), 1e9)
    top = float(probe.time[0]) * 0.9
    lo = top / SPREAD
    frac = ((np.arange(B) * 11) % B) / (B - 1)
    u = np.where(frac < 0.9, lo + (frac / 0.9) * lo * 0.25,
                 lo + (top - lo) * (frac - 0.9) / 0.1).astype(np.float32)
    m = np.full(B, 2_000_000, np.int32)

    # ---- baseline: one pmapped round, [2, 128] lanes, full horizons
    def one(s, p, uu, mm):
        return sim._run(s, uu, mm, params=p)
    pm = jax.pmap(jax.vmap(one), donate_argnums=(0,))
    mesh = lambda t: jax.tree.map(
        lambda x: x.reshape((D, B // D) + x.shape[1:]), t)
    pbs = mesh(pb)
    us, ms = u.reshape(D, B // D), m.reshape(D, B // D)
    base_out = pm(mesh(stack_states(st, B)), pbs, us, ms)  # compile
    jax.block_until_ready(base_out.time)
    dt_base = float("inf")
    for _ in range(REPS):
        sb = jax.block_until_ready(mesh(stack_states(st, B)))
        t0 = time.perf_counter()
        base_out = pm(sb, pbs, us, ms)
        base_out.time.block_until_ready()
        dt_base = min(dt_base, time.perf_counter() - t0)

    # ---- sharded rounds: ladder + global cross-shard rebalancing
    runner = BatchRunner(sim)
    out = runner.run_rounds(st, pb, u, shard=True)   # compile + autotune
    out.time.block_until_ready()
    np.testing.assert_array_equal(                   # same computation
        np.asarray(out.time), np.asarray(base_out.time).reshape(B))
    out = runner.run_rounds(st, pb, u, shard=True)   # narrowed-ladder warm
    out.time.block_until_ready()
    dt_rounds = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = runner.run_rounds(st, pb, u, shard=True)
        out.time.block_until_ready()
        dt_rounds = min(dt_rounds, time.perf_counter() - t0)

    print(json.dumps({
        "dt_base": dt_base, "dt_rounds": dt_rounds, "B": B,
        "chunk": runner.last_rounds["chunk"],
        "rounds": runner.last_rounds["rounds"],
        "shard": runner.last_rounds["shard"]}))
""")


def bench():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    r = subprocess.run([sys.executable, "-c", _WORKER],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"sharded worker failed: {r.stderr[-3000:]}")
    d = json.loads(r.stdout.strip().splitlines()[-1])
    b, base_cps = d["B"], d["B"] / d["dt_base"]
    cps = b / d["dt_rounds"]
    return [
        {
            "name": "sharded_sweep/pmap_monolith_B256_mixed",
            "us_per_call": d["dt_base"] * 1e6,
            "derived": f"{base_cps:.1f} configs/s (one pmap round, "
                       f"[2, {b // 2}] lanes, ~8x horizon spread)",
            "configs_per_sec": base_cps,
        },
        {
            "name": "sharded_sweep/sharded_rounds_B256_mixed",
            "us_per_call": d["dt_rounds"] * 1e6,
            "derived": f"{cps:.1f} configs/s "
                       f"({cps / base_cps:.2f}x pmap monolith, "
                       f"chunk {d['chunk']}, {d['rounds']} rounds, "
                       f"{d['shard']} shards) "
                       f"[acceptance: >=1.5x pmap monolith]",
            "configs_per_sec": cps,
            "chunk": d["chunk"],
            "rounds": d["rounds"],
            "shards": d["shard"],
            "speedup_vs_pmap": cps / base_cps,
        },
    ]
