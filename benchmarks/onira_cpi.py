"""Paper Fig. 12/13: Onira CPI accuracy vs the analytic pipeline reference
(RTL stand-in) + MLP scaling + burst behaviours."""
import time

from repro.sims.onira import (MICROBENCHES, analytic_cpi, run_microbenches,
                              run_mlp_sweep)


def bench():
    rows = []
    t0 = time.perf_counter()
    res = run_microbenches()
    dt = time.perf_counter() - t0
    errs = []
    for name, r in res.items():
        ref = analytic_cpi(name)
        err = abs(r["cpi"] - ref) / ref
        errs.append(err)
        rows.append({
            "name": f"onira_cpi/{name}",
            "us_per_call": dt / len(res) * 1e6,
            "derived": (f"cpi={r['cpi']:.3f} ref={ref:.3f} "
                        f"err={err*100:.1f}% (paper band: 10-20%)"),
        })
    mlp = run_mlp_sweep()
    mono = all(mlp[a] >= mlp[b] - 1e-6
               for a, b in zip(list(mlp)[:-1], list(mlp)[1:]))
    rows.append({
        "name": "onira_cpi/MLP_sweep",
        "us_per_call": 0.0,
        "derived": ("cpi(N)=" +
                    ",".join(f"{k}:{v:.2f}" for k, v in mlp.items()) +
                    f" saturating={mono} (paper Fig 13a)"),
    })
    rows.append({
        "name": "onira_cpi/max_err",
        "us_per_call": 0.0,
        "derived": f"max_cpi_err={max(errs)*100:.1f}% (paper: 10-20%)",
    })
    return rows
