"""Benchmark harness — one module per paper table/figure.

  Fig 9a/9b  smart_ticking        speedup + accuracy
  Fig 10     parallel_sim         transparent parallelism scaling
  Fig 11     tracing_overhead     tracer-mix slowdown
  Fig 12/13  onira_cpi            RISC-V timing-model CPI accuracy
  Fig 14     triosim_validation   DP/TP/PP step-time validation
  (framework) kernels             attention/SSD algorithm benchmarks
  (dse)      dse_throughput       batched-sweep configs/sec (DSE.md)
  (dse)      struct_sweep         topology-family shape sweep vs per-shape
                                  rebuild+recompile (DSE.md families) +
                                  two-process persistent-cache cold start
  (dse)      search_convergence   successive-halving search vs exhaustive
                                  sweep: objective gap + cycle budget
                                  (DSE.md "Search")
  (dse)      sharded_sweep        2-device sharded rounds vs the monolithic
                                  pmap round (DSE.md "Sharded sweeps")

Prints ``name,us_per_call,derived`` CSV.  Roofline terms for the assigned
architectures come from the dry-run (see launch/dryrun.py + EXPERIMENTS.md);
they are analysis artifacts, not wall-time benchmarks.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmark module names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write per-benchmark results (us_per_call + "
                         "derived metrics) as JSON, e.g. BENCH_engine.json, "
                         "so future PRs have a perf trajectory to compare")
    args = ap.parse_args()

    from . import (dse_throughput, kernels, onira_cpi, parallel_sim,
                   pdes_scaling, search_convergence, sharded_sweep,
                   smart_ticking, struct_sweep, tracing_overhead,
                   triosim_validation)
    modules = {
        "smart_ticking": smart_ticking,
        "parallel_sim": parallel_sim,
        "tracing_overhead": tracing_overhead,
        "onira_cpi": onira_cpi,
        "triosim_validation": triosim_validation,
        "kernels": kernels,
        "pdes_scaling": pdes_scaling,
        "dse_throughput": dse_throughput,
        "struct_sweep": struct_sweep,
        "search_convergence": search_convergence,
        "sharded_sweep": sharded_sweep,
    }
    if args.only:
        modules = {k: v for k, v in modules.items() if k in args.only}

    print("name,us_per_call,derived")
    failures = 0
    results: dict[str, dict] = {}
    for name, mod in modules.items():
        try:
            for row in mod.bench():
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"")
                sys.stdout.flush()
                results[row["name"]] = {
                    k: v for k, v in row.items() if k != "name"}
        except Exception as e:  # keep the harness going, report at exit
            failures += 1
            print(f"{name},ERROR,\"{e!r}\"")
            results[name] = {"error": repr(e)}
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(results, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
