"""Paper Fig. 11 (§4.4): tracing-system overhead.

Baseline: memsys run with no collection beyond core stats.  Traced: the
§4.4-style mix — periodic buffer-level sampling on every port (the paper's
specialized port/buffer tracers), chunked RTM monitoring, and a full DB
flush of busy-time + buffer-level series.  Paper reports ~20% slowdown.

The second case is this repo's campaign-telemetry bar (OBSERVABILITY.md):
a B=64 streaming sweep with the JSONL sink attached vs telemetry-off.
Bus events are host-side bookkeeping between dispatches, so the gate is
much harsher than the paper's engine-tracer one: ≤5% slowdown AND
bit-identical result rows (both gated in CI from BENCH_trace.json)."""
import os
import statistics
import tempfile
import time

import jax
import numpy as np

from repro.core.monitor import Monitor
from repro.core.tracers import DBTracer, flush_engine_trace
from repro.dse import SweepSpec, memoize_build, run_sweep
from repro.obs import BUS, JsonlSink
from repro.sims.memsys import build, finish_stats


def _horizon(n_cores, n_reqs):
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs)
    out = sim.run(st, until=100000.0)
    return float(np.ceil(finish_stats(sim, out)["virtual_time"])) + 64


def _run_plain(n_cores, n_reqs, horizon):
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs)
    sim.run(sim.copy_state(st), until=horizon).time.block_until_ready()
    st2 = jax.block_until_ready(sim.copy_state(st))
    t0 = time.perf_counter()
    sim.run(st2, until=horizon).time.block_until_ready()
    return time.perf_counter() - t0


def _run_traced(n_cores, n_reqs, horizon):
    # §4.4-style mix over the workload's span: periodic buffer-level
    # recorder (every 64 cycles, the paper's port/buffer tracers) + RTM
    # monitoring chunks + full DB flush of busy/buffer series
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                    sample_period=64.0)

    def once():
        mon = Monitor(sim, sim.copy_state(st))
        final, _ = mon.run_monitored(until=horizon, chunk=horizon / 8,
                                     verbose=False)
        with tempfile.TemporaryDirectory() as d:
            db = DBTracer(os.path.join(d, "t.db"))
            flush_engine_trace(sim, final, db)
            db.close()

    once()                                  # compile
    t0 = time.perf_counter()
    once()
    return time.perf_counter() - t0


def _campaign_telemetry(pairs=7, warmup=2):
    """B=64 streaming sweep: telemetry-off vs JSONL-sink-on.

    One memoized build serves both legs (identical executables).  Wall
    time on a shared CI box drifts monotonically (frequency scaling,
    cache warm-up), so independent leg medians are unusable at a 5%
    bar; instead each off-leg is paired with the immediately following
    on-leg and the gate compares the **median of the per-pair ratios**
    — the drift cancels inside a pair.  Rows must come back
    bit-identical.
    """
    bf = memoize_build(lambda: build(n_cores=4, pattern="mixed",
                                     n_reqs=16, donate=True))
    spec = SweepSpec.grid({
        "conn_latency[-1]": [float(v) for v in range(4, 36, 2)],   # 16
        "kind.l1.extra_hit_rate": [0.0, 0.25, 0.5, 0.75],          # x4
    })
    assert len(spec) == 64
    kw = dict(until=3000.0, extract=None, chunk=16)

    def leg_off():
        t0 = time.perf_counter()
        rows = run_sweep(bf, spec, **kw)
        return time.perf_counter() - t0, rows

    def leg_on(path):
        sink = BUS.attach(JsonlSink(path))
        try:
            t0 = time.perf_counter()
            rows = run_sweep(bf, spec, **kw)
            dt = time.perf_counter() - t0
        finally:
            BUS.detach(sink)
            sink.close()
        return dt, rows

    rows_off = rows_on = None
    ratios, offs, ons = [], [], []
    with tempfile.TemporaryDirectory() as d:
        for i in range(warmup):             # compile + settle both legs
            leg_off()
            leg_on(os.path.join(d, f"w{i}.jsonl"))
        for i in range(pairs):
            t_off, rows_off = leg_off()
            t_on, rows_on = leg_on(os.path.join(d, f"c{i}.jsonl"))
            offs.append(t_off)
            ons.append(t_on)
            ratios.append(t_on / t_off)
        events = sum(1 for _ in open(os.path.join(d, "c0.jsonl"))) - 1

    identical = len(rows_off) == len(rows_on) and all(
        ra.keys() == rb.keys()
        and all(ra[k] == rb[k] for k in ra)
        for ra, rb in zip(rows_off, rows_on))
    return {"slowdown": statistics.median(ratios),
            "on_s": statistics.median(ons),
            "off_s": statistics.median(offs),
            "rows_identical": identical, "events": events}


def bench(n_cores=16, n_reqs=96):
    horizon = _horizon(n_cores, n_reqs)
    base = _run_plain(n_cores, n_reqs, horizon)
    traced = _run_traced(n_cores, n_reqs, horizon)
    slowdown = traced / base
    c = _campaign_telemetry()
    return [{
        "name": "tracing_overhead/memsys",
        "us_per_call": traced * 1e6,
        "derived": (f"slowdown={slowdown:.2f}x over {base*1e3:.1f}ms base "
                    f"(paper: ~1.20x)"),
    }, {
        "name": "tracing_overhead/campaign_telemetry",
        "us_per_call": c["on_s"] * 1e6,
        "slowdown": c["slowdown"],
        "rows_identical": bool(c["rows_identical"]),
        "events": int(c["events"]),
        "derived": (f"B=64 sweep: JSONL-on {c['on_s']*1e3:.1f}ms vs off "
                    f"{c['off_s']*1e3:.1f}ms = {c['slowdown']:.3f}x "
                    f"median pair ratio ({c['events']} events; gate "
                    f"<=1.05x, rows identical={c['rows_identical']})"),
    }]
