"""Paper Fig. 11 (§4.4): tracing-system overhead.

Baseline: memsys run with no collection beyond core stats.  Traced: the
§4.4-style mix — periodic buffer-level sampling on every port (the paper's
specialized port/buffer tracers), chunked RTM monitoring, and a full DB
flush of busy-time + buffer-level series.  Paper reports ~20% slowdown."""
import os
import tempfile
import time

import jax
import numpy as np

from repro.core.monitor import Monitor
from repro.core.tracers import DBTracer, flush_engine_trace
from repro.sims.memsys import build, finish_stats


def _horizon(n_cores, n_reqs):
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs)
    out = sim.run(st, until=100000.0)
    return float(np.ceil(finish_stats(sim, out)["virtual_time"])) + 64


def _run_plain(n_cores, n_reqs, horizon):
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs)
    sim.run(sim.copy_state(st), until=horizon).time.block_until_ready()
    st2 = jax.block_until_ready(sim.copy_state(st))
    t0 = time.perf_counter()
    sim.run(st2, until=horizon).time.block_until_ready()
    return time.perf_counter() - t0


def _run_traced(n_cores, n_reqs, horizon):
    # §4.4-style mix over the workload's span: periodic buffer-level
    # recorder (every 64 cycles, the paper's port/buffer tracers) + RTM
    # monitoring chunks + full DB flush of busy/buffer series
    sim, st = build(n_cores=n_cores, pattern="mixed", n_reqs=n_reqs,
                    sample_period=64.0)

    def once():
        mon = Monitor(sim, sim.copy_state(st))
        final, _ = mon.run_monitored(until=horizon, chunk=horizon / 8,
                                     verbose=False)
        with tempfile.TemporaryDirectory() as d:
            db = DBTracer(os.path.join(d, "t.db"))
            flush_engine_trace(sim, final, db)
            db.close()

    once()                                  # compile
    t0 = time.perf_counter()
    once()
    return time.perf_counter() - t0


def bench(n_cores=16, n_reqs=96):
    horizon = _horizon(n_cores, n_reqs)
    base = _run_plain(n_cores, n_reqs, horizon)
    traced = _run_traced(n_cores, n_reqs, horizon)
    slowdown = traced / base
    return [{
        "name": "tracing_overhead/memsys",
        "us_per_call": traced * 1e6,
        "derived": (f"slowdown={slowdown:.2f}x over {base*1e3:.1f}ms base "
                    f"(paper: ~1.20x)"),
    }]
