"""Paper Fig. 10: transparent parallel simulation.

Hardware adaptation (DESIGN.md §7): this container has ONE core, so Akita's
multi-core wall-clock speedup is not measurable.  The engine's parallelism
is *vector* parallelism — all instances of a kind tick in one fused vmap —
so we measure how wall time scales as the simulated system grows: simulating
N× more cores costs far less than N× more wall time.  The cross-device half
(conservative PDES over ``shard_map``) is exercised by the 8-device
subprocess tests and the 512-chip dry-run."""
import time

import jax
import numpy as np

from repro.sims.memsys import build, finish_stats


def _wall(n_cores, pattern="mixed", n_reqs=64):
    # independent tiles: pure lane-scaling, no shared-DRAM contention (which
    # would conflate queueing with engine overhead)
    sim, st = build(n_cores=n_cores, pattern=pattern, n_reqs=n_reqs,
                    private_dram=True)
    out = sim.run(sim.copy_state(st), until=100000.0)
    out.time.block_until_ready()
    st2 = jax.block_until_ready(sim.copy_state(st))  # run() consumes st2
    t0 = time.perf_counter()
    out = sim.run(st2, until=100000.0)
    out.time.block_until_ready()
    return time.perf_counter() - t0, finish_stats(sim, out)


def bench():
    rows = []
    base_n = 4
    base_t, _ = _wall(base_n)
    for n in (4, 8, 16, 32, 64):
        dt, stats = _wall(n)
        # effective parallel speedup: simulated-components-per-wall-second,
        # normalized to the 4-core system
        eff = (n / dt) / (base_n / base_t)
        rows.append({
            "name": f"parallel_sim/{n}cores",
            "us_per_call": dt * 1e6,
            "derived": (f"eff_parallel_speedup={eff:.2f}x "
                        f"(paper 4-16 cores: 1.88-2.38x) "
                        f"epochs={stats['epochs']}"),
        })
    return rows
