"""Search convergence vs the exhaustive sweep it replaces (DSE.md
"Search").

The memsys 3-axis grid (8 crossbar latencies x 6 L1 hit-rate boosts x 4
DRAM periods = 192 design points) is swept exhaustively at the full
horizon, then searched with seeded ``SuccessiveHalving`` over the same
grid — budgets accounted in *simulated cycles* (what a simulation
campaign actually pays; wall-clock on this drifty box is reported but
not gated).  The objective is the estimated completion time
``est_finish = virtual_time * total_reqs / reqs_done`` — equal to the
true completion time once a config drains, and a throughput-based
estimate mid-flight, so short-horizon rungs rank configs meaningfully.

The search promotes **warm** (DSE.md "Warm-state promotions"): a
promoted config resumes from its frozen rung-end state instead of
replaying from cycle 0, so the budget counts only horizon *increments*
— a config that climbs the whole ladder costs its final virtual time,
not the sum of every rung's replay.  ``budget_cycles_replay`` quotes
what the same trajectory would have cost with replay promotion (the
pre-warm accounting), for the trajectory they are provably identical
(tests/dse/test_warm_resume.py).

Acceptance (CI-gated via BENCH_search.json):

* ``gap_pct <= 2`` — the search's best config is within 2% of the
  exhaustive optimum objective;
* ``budget_fraction <= 0.20`` — for at most 20% of the exhaustive
  simulated-cycle budget (warm incremental accounting; was <= 0.40
  under replay promotion);
* ``resume_identical`` — a search interrupted mid-ladder and restored
  from its ``repro.ckpt`` rung checkpoint (``save_search`` /
  ``load_search``: SearchState JSON + promoted configs' frozen states)
  resumes the bit-identical trajectory — same trials, same best, same
  cumulative budget.

Every round boundary also writes a rung checkpoint; their sizes are
reported (``rung_checkpoints`` row) and uploaded as a CI artifact.

The sequential baselines are quoted exactly as in BENCH_dse.json: the
pre-SimParams rebuild+recompile-per-point workflow and the shared-jit
sequential workflow, measured on small samples adjacent to the gated
measurement (a rate suffices; this box's absolute throughput drifts
~2x between runs).
"""
import os
import tempfile
import time

import jax
import numpy as np

from repro.dse import (SuccessiveHalving, SweepSpec, apply_point,
                       load_search, memoize_build, run_search, run_sweep,
                       save_search)
from repro.sims.memsys import build

AXES = {
    "conn_latency[-1]": [10.0, 20.0, 30.0, 40.0, 55.0, 70.0, 85.0, 100.0],
    "kind.l1.extra_hit_rate": [0.0, 0.15, 0.3, 0.45, 0.6, 0.8],
    "period.dram": [1.0, 2.0, 3.0, 4.0],
}
N_CORES, N_REQS = 8, 24
MAX_H = 5600.0          # ~1.1x the slowest config's drain time
ETA = 3
# 5 rungs: 192 -> 64 -> 22 -> 8 -> 3 survivors.  Warm promotion makes
# the deeper ladder strictly cheaper: the extra bottom rung prunes 2/3
# of the grid at 1/81 of the horizon, and survivors pay increments only
MIN_H = MAX_H / ETA**4
REBUILD_SAMPLE = 3
SHAREDJIT_SAMPLE = 12
RESUME_AFTER_ROUND = 2  # snapshot boundary for the mid-search resume


def _sh(pool, state=None):
    return SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                             min_horizon=MIN_H, eta=ETA, seed=0,
                             state=state)


def bench():
    rows = []

    def build_fn():
        # super-epoch fusion is observation-invariant (ENGINE_PERF.md)
        # and ~30% faster wall on this grid — results are bit-identical
        return build(n_cores=N_CORES, pattern="mixed", n_reqs=N_REQS,
                     donate=True, super_epoch=4)

    bf = memoize_build(build_fn)
    sim, st = bf()
    total = int(np.sum(np.asarray(st.comp_state["core"]["remaining"])))

    def extract(sim, s):
        rem = int(np.sum(np.asarray(s.comp_state["core"]["remaining"])))
        vt = float(s.time)
        done = total - rem
        return {"virtual_time": vt, "remaining": rem,
                "est_finish": vt * total / max(done, 1)}

    pool = SweepSpec.grid(AXES)
    n = len(pool)

    # exhaustive sweep at the full horizon: the optimum + cycle budget
    # the search is judged against (also compiles/warms the shared
    # runner the search rounds reuse)
    t0 = time.perf_counter()
    full = run_sweep(bf, pool, until=MAX_H, extract=extract)
    dt_full = time.perf_counter() - t0
    assert all(r["remaining"] == 0 for r in full), "raise MAX_H"
    opt = min(r["est_finish"] for r in full)
    exhaustive_budget = sum(r["virtual_time"] for r in full)
    rows.append({
        "name": "search_convergence/exhaustive",
        "us_per_call": dt_full / n * 1e6,
        "derived": f"{n}-point grid optimum {opt:.0f} cycles for "
                   f"{exhaustive_budget:.0f} simulated cycles "
                   f"({n / dt_full:.1f} configs/s)",
        "optimum": opt,
        "budget_cycles": exhaustive_budget,
        "configs_per_sec": n / dt_full,
    })

    # sequential baselines, quoted as in BENCH_dse.json ----------------
    t0 = time.perf_counter()
    for i in range(REBUILD_SAMPLE):
        s_i, st_i = build(n_cores=N_CORES, pattern="mixed", n_reqs=N_REQS,
                          dram_latency=10.0 + 10.0 * i, donate=True)
        out = s_i.run(st_i, MAX_H)
        out.time.block_until_ready()
    dt = time.perf_counter() - t0
    rebuild_cps = REBUILD_SAMPLE / dt
    rows.append({
        "name": "search_convergence/sequential_rebuild",
        "us_per_call": dt / REBUILD_SAMPLE * 1e6,
        "derived": f"{rebuild_cps:.2f} configs/s (build+compile+run per "
                   f"point, {REBUILD_SAMPLE}-point sample; exhaustive "
                   f"grid at this rate: {n / rebuild_cps:.0f}s)",
        "configs_per_sec": rebuild_cps,
    })

    sub = list(pool)[::n // SHAREDJIT_SAMPLE][:SHAREDJIT_SAMPLE]
    base = sim.default_params()
    sub_params = [apply_point(base, p) for p in sub]
    warm = sim.run(sim.copy_state(st), MAX_H, params=sub_params[0])
    warm.time.block_until_ready()
    states = [jax.block_until_ready(sim.copy_state(st)) for _ in sub]
    t0 = time.perf_counter()
    outs = [sim.run(s, MAX_H, params=p) for s, p in zip(states, sub_params)]
    jax.block_until_ready(outs[-1].time)
    dt = time.perf_counter() - t0
    shared_cps = len(sub) / dt
    rows.append({
        "name": "search_convergence/sequential_sharedjit",
        "us_per_call": dt / len(sub) * 1e6,
        "derived": f"{shared_cps:.1f} configs/s (one compile, sequential "
                   f"runs, {len(sub)}-point sample)",
        "configs_per_sec": shared_cps,
    })

    # the search: seeded warm successive halving over the same grid ----
    # every round boundary writes a repro.ckpt rung checkpoint (the
    # SearchState JSON plus the promoted configs' frozen lane states);
    # the saves are timed separately and excluded from the search wall
    ckpt_root = tempfile.mkdtemp(prefix="rung_ckpt_")
    saves = []

    def snapshot(drv):
        t = time.perf_counter()
        root = os.path.join(ckpt_root, f"round{drv.state.round}")
        save_search(root, drv)
        saves.append((drv.state.round, root, time.perf_counter() - t))

    t0 = time.perf_counter()
    res = run_search(bf, _sh(pool), extract=extract, callback=snapshot)
    dt_total = time.perf_counter() - t0
    dt_save = sum(s for _, _, s in saves)
    dt_sh = dt_total - dt_save
    gap_pct = (res.best["est_finish"] / opt - 1.0) * 100.0
    frac = res.budget / exhaustive_budget
    # what the identical trajectory costs under replay promotion (every
    # rung re-run from cycle 0) — the pre-warm accounting
    replay_budget = sum(t["virtual_time"] for t in res.rows)

    # mid-search resume: restore the rung checkpoint written after
    # RESUME_AFTER_ROUND and replay the remaining rounds — rows, best
    # AND cumulative budget must be bit-identical (completed rungs are
    # restored, not re-paid)
    rnd, path, _ = next(s for s in saves if s[0] == RESUME_AFTER_ROUND)
    state, handles = load_search(path, st)
    drv = _sh(pool, state=state)
    drv.adopt_handles(handles)
    resumed = run_search(bf, drv, extract=extract)
    resume_identical = (resumed.rows == res.rows
                        and resumed.budget == res.budget
                        and resumed.best == res.best)

    rows.append({
        "name": "search_convergence/successive_halving",
        "us_per_call": dt_sh / max(len(res.rows), 1) * 1e6,
        "derived": f"best {res.best['est_finish']:.0f} cycles "
                   f"(gap {gap_pct:.2f}%) for {res.budget:.0f} simulated "
                   f"cycles = {frac * 100:.1f}% of exhaustive "
                   f"(replay accounting: {replay_budget:.0f} = "
                   f"{replay_budget / exhaustive_budget * 100:.1f}%), "
                   f"{len(res.rows)} trials / {res.rounds} rounds, "
                   f"resume_identical={resume_identical} "
                   f"[acceptance: gap<=2%, budget<=20%, ckpt resume]",
        "best_objective": res.best["est_finish"],
        "optimum": opt,
        "gap_pct": gap_pct,
        "budget_cycles": res.budget,
        "budget_fraction": frac,
        "budget_cycles_replay": replay_budget,
        "budget_fraction_replay": replay_budget / exhaustive_budget,
        "trials": len(res.rows),
        "rounds": res.rounds,
        "resume_identical": bool(resume_identical),
        "resume_after_round": rnd,
        "wall_s": dt_sh,
        "wall_s_exhaustive": dt_full,
    })

    # rung checkpoint sizes (uploaded as a CI artifact via this JSON)
    def _dir_bytes(p):
        return sum(os.path.getsize(os.path.join(r, f))
                   for r, _, fs in os.walk(p) for f in fs)

    sizes = {f"round{r}": _dir_bytes(p) for r, p, _ in saves}
    total_b = sum(sizes.values())
    rows.append({
        "name": "search_convergence/rung_checkpoints",
        "us_per_call": dt_save / max(len(saves), 1) * 1e6,
        "derived": f"{len(saves)} round checkpoints, "
                   f"{total_b / 1024:.0f} KiB total "
                   f"(max {max(sizes.values()) / 1024:.0f} KiB), "
                   f"{dt_save * 1e3:.0f} ms save wall",
        "bytes_per_round": sizes,
        "total_bytes": total_b,
        "save_wall_s": dt_save,
    })
    return rows
