"""Search convergence vs the exhaustive sweep it replaces (DSE.md
"Search").

The memsys 3-axis grid (8 crossbar latencies x 6 L1 hit-rate boosts x 4
DRAM periods = 192 design points) is swept exhaustively at the full
horizon, then searched with seeded ``SuccessiveHalving`` over the same
grid — budgets accounted in *simulated cycles* (what a simulation
campaign actually pays; wall-clock on this drifty box is reported but
not gated).  The objective is the estimated completion time
``est_finish = virtual_time * total_reqs / reqs_done`` — equal to the
true completion time once a config drains, and a throughput-based
estimate mid-flight, so short-horizon rungs rank configs meaningfully.

Acceptance (CI-gated via BENCH_search.json):

* ``gap_pct <= 2`` — the search's best config is within 2% of the
  exhaustive optimum objective;
* ``budget_fraction <= 0.40`` — for at most 40% of the exhaustive
  simulated-cycle budget;
* ``resume_identical`` — a ``SearchState`` snapshot taken mid-search
  resumes the bit-identical trajectory (same trials, same budget).

The sequential baselines are quoted exactly as in BENCH_dse.json: the
pre-SimParams rebuild+recompile-per-point workflow and the shared-jit
sequential workflow, measured on small samples adjacent to the gated
measurement (a rate suffices; this box's absolute throughput drifts
~2x between runs).
"""
import time

import jax
import numpy as np

from repro.dse import (SearchState, SuccessiveHalving, SweepSpec,
                       apply_point, memoize_build, run_search, run_sweep)
from repro.sims.memsys import build

AXES = {
    "conn_latency[-1]": [10.0, 20.0, 30.0, 40.0, 55.0, 70.0, 85.0, 100.0],
    "kind.l1.extra_hit_rate": [0.0, 0.15, 0.3, 0.45, 0.6, 0.8],
    "period.dram": [1.0, 2.0, 3.0, 4.0],
}
N_CORES, N_REQS = 8, 24
MAX_H = 5600.0          # ~1.1x the slowest config's drain time
ETA = 3
MIN_H = MAX_H / ETA**3  # 4 rungs: 192 -> 64 -> 22 -> 8 survivors
REBUILD_SAMPLE = 3
SHAREDJIT_SAMPLE = 12
RESUME_AFTER_ROUND = 2  # snapshot boundary for the mid-search resume


def _sh(pool, state=None):
    return SuccessiveHalving(pool, "est_finish", max_horizon=MAX_H,
                             min_horizon=MIN_H, eta=ETA, seed=0,
                             state=state)


def bench():
    rows = []

    def build_fn():
        # super-epoch fusion is observation-invariant (ENGINE_PERF.md)
        # and ~30% faster wall on this grid — results are bit-identical
        return build(n_cores=N_CORES, pattern="mixed", n_reqs=N_REQS,
                     donate=True, super_epoch=4)

    bf = memoize_build(build_fn)
    sim, st = bf()
    total = int(np.sum(np.asarray(st.comp_state["core"]["remaining"])))

    def extract(sim, s):
        rem = int(np.sum(np.asarray(s.comp_state["core"]["remaining"])))
        vt = float(s.time)
        done = total - rem
        return {"virtual_time": vt, "remaining": rem,
                "est_finish": vt * total / max(done, 1)}

    pool = SweepSpec.grid(AXES)
    n = len(pool)

    # exhaustive sweep at the full horizon: the optimum + cycle budget
    # the search is judged against (also compiles/warms the shared
    # runner the search rounds reuse)
    t0 = time.perf_counter()
    full = run_sweep(bf, pool, until=MAX_H, extract=extract)
    dt_full = time.perf_counter() - t0
    assert all(r["remaining"] == 0 for r in full), "raise MAX_H"
    opt = min(r["est_finish"] for r in full)
    exhaustive_budget = sum(r["virtual_time"] for r in full)
    rows.append({
        "name": "search_convergence/exhaustive",
        "us_per_call": dt_full / n * 1e6,
        "derived": f"{n}-point grid optimum {opt:.0f} cycles for "
                   f"{exhaustive_budget:.0f} simulated cycles "
                   f"({n / dt_full:.1f} configs/s)",
        "optimum": opt,
        "budget_cycles": exhaustive_budget,
        "configs_per_sec": n / dt_full,
    })

    # sequential baselines, quoted as in BENCH_dse.json ----------------
    t0 = time.perf_counter()
    for i in range(REBUILD_SAMPLE):
        s_i, st_i = build(n_cores=N_CORES, pattern="mixed", n_reqs=N_REQS,
                          dram_latency=10.0 + 10.0 * i, donate=True)
        out = s_i.run(st_i, MAX_H)
        out.time.block_until_ready()
    dt = time.perf_counter() - t0
    rebuild_cps = REBUILD_SAMPLE / dt
    rows.append({
        "name": "search_convergence/sequential_rebuild",
        "us_per_call": dt / REBUILD_SAMPLE * 1e6,
        "derived": f"{rebuild_cps:.2f} configs/s (build+compile+run per "
                   f"point, {REBUILD_SAMPLE}-point sample; exhaustive "
                   f"grid at this rate: {n / rebuild_cps:.0f}s)",
        "configs_per_sec": rebuild_cps,
    })

    sub = list(pool)[::n // SHAREDJIT_SAMPLE][:SHAREDJIT_SAMPLE]
    base = sim.default_params()
    sub_params = [apply_point(base, p) for p in sub]
    warm = sim.run(sim.copy_state(st), MAX_H, params=sub_params[0])
    warm.time.block_until_ready()
    states = [jax.block_until_ready(sim.copy_state(st)) for _ in sub]
    t0 = time.perf_counter()
    outs = [sim.run(s, MAX_H, params=p) for s, p in zip(states, sub_params)]
    jax.block_until_ready(outs[-1].time)
    dt = time.perf_counter() - t0
    shared_cps = len(sub) / dt
    rows.append({
        "name": "search_convergence/sequential_sharedjit",
        "us_per_call": dt / len(sub) * 1e6,
        "derived": f"{shared_cps:.1f} configs/s (one compile, sequential "
                   f"runs, {len(sub)}-point sample)",
        "configs_per_sec": shared_cps,
    })

    # the search: seeded successive halving over the same grid ---------
    snaps = []
    t0 = time.perf_counter()
    res = run_search(bf, _sh(pool), extract=extract,
                     callback=lambda d: snaps.append(d.state.to_json()))
    dt_sh = time.perf_counter() - t0
    gap_pct = (res.best["est_finish"] / opt - 1.0) * 100.0
    frac = res.budget / exhaustive_budget

    # mid-search resume: restore the round-boundary snapshot and replay
    # the remaining rounds — the trajectory must be bit-identical
    state = SearchState.from_json(snaps[RESUME_AFTER_ROUND - 1])
    resumed = run_search(bf, _sh(pool, state=state), extract=extract)
    resume_identical = (resumed.rows == res.rows
                        and resumed.budget == res.budget
                        and resumed.best == res.best)

    rows.append({
        "name": "search_convergence/successive_halving",
        "us_per_call": dt_sh / max(len(res.rows), 1) * 1e6,
        "derived": f"best {res.best['est_finish']:.0f} cycles "
                   f"(gap {gap_pct:.2f}%) for {res.budget:.0f} simulated "
                   f"cycles = {frac * 100:.1f}% of exhaustive, "
                   f"{len(res.rows)} trials / {res.rounds} rounds, "
                   f"resume_identical={resume_identical} "
                   f"[acceptance: gap<=2%, budget<=40%, resume]",
        "best_objective": res.best["est_finish"],
        "optimum": opt,
        "gap_pct": gap_pct,
        "budget_cycles": res.budget,
        "budget_fraction": frac,
        "trials": len(res.rows),
        "rounds": res.rounds,
        "resume_identical": bool(resume_identical),
        "wall_s": dt_sh,
        "wall_s_exhaustive": dt_full,
    })
    return rows
