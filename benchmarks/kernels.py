"""Framework kernel benchmarks (CPU): blockwise (flash-style) attention vs
naive O(S^2)-materializing attention; chunked SSD vs sequential recurrence.
The Pallas kernels themselves target TPU (interpret mode is a correctness
harness, not a perf path); these measure the same *algorithms* in XLA:CPU."""
import math
import time

import jax
import jax.numpy as jnp

from repro.models.attention import blockwise_attention
from repro.models.ssm import ssd_chunked, ssd_ref


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _naive_attention(q, k, v, scale):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    S = q.shape[1]
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def bench():
    rows = []
    B, S, H, hd = 1, 2048, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    scale = 1.0 / math.sqrt(hd)

    fa = jax.jit(lambda q, k, v: blockwise_attention(
        q, k, v, pos, pos, causal=True, chunk=512))
    nv = jax.jit(lambda q, k, v: _naive_attention(q, k, v, scale))
    t_fa, t_nv = _time(fa, q, k, v), _time(nv, q, k, v)
    rows.append({"name": "kernels/flash_vs_naive_attention_2k",
                 "us_per_call": t_fa * 1e6,
                 "derived": f"naive={t_nv*1e6:.0f}us ratio={t_nv/t_fa:.2f}x"})

    B, S, Hh, P, N = 2, 2048, 12, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    xs = jax.random.normal(ks[0], (B, S, Hh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Hh)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    C_ = jax.random.normal(jax.random.PRNGKey(9), (B, S, N), jnp.float32)
    ch = jax.jit(lambda *a: ssd_chunked(*a, chunk=128))
    sq = jax.jit(ssd_ref)
    t_ch, t_sq = _time(ch, xs, dt, A, B_, C_), _time(sq, xs, dt, A, B_, C_)
    rows.append({"name": "kernels/ssd_chunked_vs_sequential_2k",
                 "us_per_call": t_ch * 1e6,
                 "derived": f"seq={t_sq*1e6:.0f}us speedup={t_sq/t_ch:.2f}x"})
    return rows
